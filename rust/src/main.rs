//! `cnmt` — the C-NMT launcher.
//!
//! ```text
//! cnmt experiment table1|fig2a|fig3|fig4|all [flags]   reproduce the paper
//! cnmt bench sched [--json]                            scheduler perf numbers → BENCH_sched.json
//! cnmt trace dump|summary|verify [flags|file]          decision-log flight recorder tooling
//! cnmt trace record|replay|info [flags|file]           binary workload traces (.ctr)
//! cnmt bench trace [--json]                            trace codec throughput → BENCH_trace.json
//! cnmt calibrate [flags]                               real-PJRT device characterisation
//! cnmt translate --model <name> --ids 5,6,7            one translation through the runtime
//! cnmt selfcheck                                       load + run every artifact
//! cnmt help
//! ```
//!
//! Common flags: `--config <json>`, `--seed <u64>`, `--requests <n>`,
//! `--out <dir>`, `--artifacts <dir>`, `--calibration <json>`.

use std::path::PathBuf;
use std::process::ExitCode;

use cnmt::config::Config;
use cnmt::corpus::LangPair;
#[cfg(feature = "pjrt")]
use cnmt::corpus::Tokenizer;
use cnmt::devices::Calibration;
use cnmt::experiments::{
    ablation, detect, energy, fig2a, fig3, fig4, fleet, load, multilevel, outage, report,
    runner, scenario, table1,
};
#[cfg(feature = "pjrt")]
use cnmt::runtime::{ArtifactManifest, Seq2SeqEngine, TranslateOptions};
use cnmt::util::Args;
#[cfg(feature = "pjrt")]
use cnmt::util::Json;
use cnmt::{Error, Result};

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("experiment") => cmd_experiment(&args),
        Some("bench") => cmd_bench(&args),
        Some("trace") => cmd_trace(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("translate") => cmd_translate(&args),
        Some("selfcheck") => cmd_selfcheck(&args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => Err(Error::Config(format!(
            "unknown subcommand `{other}` (try `cnmt help`)"
        ))),
    }
}

const HELP: &str = "\
cnmt — C-NMT: collaborative inference for neural machine translation

USAGE:
  cnmt experiment <table1|fig2a|fig3|fig4|ablation|energy|multilevel|load|fleet|outage|detect|scenario|all> [flags]
      --config <json>       load a Config (defaults = paper setup)
      --requests <n>        requests per experiment: table1 evaluation
                            requests (default 100000), requests/point of
                            the load/fleet/outage/detect sweeps (default
                            20000 each), scenario request count (default:
                            from the spec). The legacy per-sweep
                            spellings (--load-requests etc.) still work
                            and win when both are given.
      --fit <n>             characterisation inferences (default 10000)
      --seed <u64>          master seed
      --out <dir>           report directory (default reports/)
      --calibration <json>  measured calibration (default: built-in)
      --samples <n>         fig2a/fig3 sample count
      --loads <a,b,..>      load sweep: offered loads in r/s
      --closed-loop         load sweep: closed-loop clients instead of
                            open-loop Poisson arrivals (writes closed_loop.json);
                            with `experiment fleet`: the closed-loop drift
                            sweep — K clients drive the topology while its
                            lead edge gateway throttles 2.5x mid-run,
                            comparing tier-baseline vs per-device-refit
                            selection and budget-controlled hedging
                            (writes fleet_closed_loop.json)
      --clients <a,b,..>    closed loop: client counts (default 1,2,4,8,16,32,64;
                            fleet closed loop: 8,16,32,64)
      --think-ms <f>        closed loop: per-client think time (default 0)
      --threads <n>         load/fleet sweep: shard cells over n OS threads
                            (0 = all cores; reports are bit-identical
                            at any thread count; default 1)
      --shapes <a,b,..>     fleet sweep: topology presets to sweep
                            (default 1x1,4x2,8x4,hetero; any <e>x<c> works)
      --topology <json>     fleet sweep: sweep a custom topology spec
                            instead of the presets
      --offered-rps <f>     fleet sweep: offered load for --topology
                            (default 96)
      --telemetry           fleet closed loop: sample control-loop
                            telemetry (per-device gauges, phase
                            decomposition) at a fixed cadence and write
                            telemetry_drift.json instead of
                            fleet_closed_loop.json (default K = 32)
      --trace <path>        outage sweep only (crashes the lead edge
                            gateway mid-run, health-blind baseline vs
                            deadline-timer failover, writes
                            outage_sweep.json): additionally stream the
                            failover cell's full decision log (JSONL)
                            to <path> for `cnmt trace verify`
                            (with `experiment outage`, --telemetry
                            samples control-loop gauges in both cells
                            and adds a `telemetry` block per policy;
                            `experiment detect` scores five fault
                            scenarios under the online detector and
                            writes detect_eval.json)
      --scenario <json>     replay a declarative ScenarioSpec
                            (time-varying load, SLO service classes,
                            drift/fault timeline) as a class-blind FIFO
                            baseline vs EDF + class-aware hedging
                            comparison, writing scenario_sweep.json.
                            Accepted by every experiment subcommand
                            (the scenario rides along after it);
                            `experiment scenario` runs it standalone
                            with the checked-in default spec
                            examples/scenarios/slo_mix.json
  cnmt bench sched [flags]  scheduler core benchmark (events/sec,
                            ns/event, sweep wall-clock at 1 vs N threads)
      --json                also write the machine-readable report
      --out <path>          report path (default reports/BENCH_sched.json)
      --requests <n>        event-loop stream length (default 20000)
      --sweep-requests <n>  requests/point for the wall-clock sweep
                            (default 4000)
      --threads <n>         parallel sweep thread count (0 = all cores)
  cnmt bench trace [flags]  binary trace codec throughput (encode and
                            decode events/sec over an in-memory stream)
      --json                also write the machine-readable report
      --out <path>          report path (default reports/BENCH_trace.json)
      --requests <n>        records per measurement (default 100000)
  cnmt trace dump [flags]   stream a full decision log (JSONL) from a
                            canned hedged-adaptive contended pair replay
      --out <path>          trace destination (default trace.jsonl)
      --requests <n>        replay length (default 2000)
      --load <f>            offered load in r/s (default 120)
      --seed <u64>          master seed (default 20220315)
  cnmt trace summary <file> per-event-tag counts, the trace span, and
                            recorder health (dropped prefix, ring
                            evictions, sink status) from the trailer
  cnmt trace verify <file>  offline replay: re-prove conservation,
                            hedge-fate partitioning, margin control law
                            and waste-budget compliance from the log
                            alone (no harness internals); fails on a
                            truncated ring window or unhealthy trailer
      --allow-truncated     verify a truncated window anyway (local
                            checks + tallies only; conservation needs
                            the full stream)
  cnmt trace record [flags] record the synthetic scenario as a compact
                            binary workload trace (.ctr: versioned
                            header, varint records, CRC-sealed blocks)
      --out <path>          trace destination (default trace.ctr)
      --requests <n>        trace length (default 100000)
      --load <f>            offered load in r/s (default 96)
      --seed <u64>          master seed (default 20220315)
      --exec-noise <f>      execution-noise std; > 0 stores explicit
                            per-record service times (default 0)
  cnmt trace replay <file> [flags]  replay a recorded trace through the
                            contended harness (EdgeOnly, CloudOnly,
                            C-NMT queue-aware, C-NMT adaptive) and
                            write a bit-deterministic trace_replay.json
      --out <dir>           report directory (default reports/)
      --threads <n>         shard the policy cells over n OS threads
                            (0 = all cores; the report is bit-identical
                            at any thread count)
  cnmt trace info <file>    validate every block CRC + the end marker
                            and print the trace summary (records, span,
                            offered load, mean n/m)
  cnmt calibrate [flags]    measure real PJRT latencies, fit T_exe planes
                            (needs the `pjrt` build feature)
      --samples <n>         measured translations per model (default 120)
      --edge-slowdown <f>   edge = local CPU x f (default 1.0)
      --cloud-speedup <f>   cloud = local CPU / f (default 5.0)
      --artifacts <dir>     artifacts directory (default artifacts/)
      --out <path>          output (default artifacts/calibration.json)
      --models <a,b>        subset of models
  cnmt translate --model <name> --ids 5,6,7 [--text \"ba de ga\"]
  cnmt selfcheck            load + execute every artifact end to end
";

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.str_opt("config") {
        Some(p) => Config::load(&PathBuf::from(p))?,
        None => Config::default(),
    };
    cfg.requests = args.usize("requests", cfg.requests)?;
    cfg.fit_inferences = args.usize("fit", cfg.fit_inferences)?;
    cfg.seed = args.u64("seed", cfg.seed)?;
    if let Some(out) = args.str_opt("out") {
        cfg.out_dir = PathBuf::from(out);
    }
    if let Some(a) = args.str_opt("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }
    if let Some(c) = args.str_opt("calibration") {
        cfg.calibration = Some(PathBuf::from(c));
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Per-sweep request count under the unified `--requests` flag. The
/// legacy per-experiment spellings (`--load-requests`,
/// `--fleet-requests`, `--outage-requests`, `--detect-requests`) remain
/// hidden aliases and — being the more specific name — win when both
/// are given.
fn sweep_requests(args: &Args, legacy: &str, default: usize) -> Result<usize> {
    let unified = args.usize("requests", default)?;
    args.usize(legacy, unified)
}

fn load_calibration(cfg: &Config) -> Result<Calibration> {
    match &cfg.calibration {
        Some(path) => {
            eprintln!("using measured calibration: {}", path.display());
            Calibration::load(path)
        }
        None => Ok(Calibration::default_paper()),
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let cfg = load_config(args)?;
    let cal = load_calibration(&cfg)?;
    let samples = args.usize("samples", 30_000)?;
    // Only the load sweep consumes its flags; on other experiments a
    // stray `--loads` stays unknown and is rejected below.
    let (load_cfg, closed_cfg) = if matches!(which.as_str(), "load" | "all") {
        let closed = args.bool("closed-loop");
        if closed {
            let mut cc = load::ClosedLoopConfig { seed: cfg.seed, ..Default::default() };
            cc.threads = runner::resolve_threads(args.usize("threads", 1)?);
            if let Some(clients) = args.str_opt("clients") {
                cc.clients = clients
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|_| {
                            Error::Config(format!("--clients: `{s}` is not an integer"))
                        })
                    })
                    .collect::<Result<_>>()?;
            }
            cc.think_s = args.f64("think-ms", 0.0)? / 1e3;
            cc.requests_per_point = sweep_requests(args, "load-requests", cc.requests_per_point)?;
            (None, Some(cc))
        } else {
            let mut lc = load::LoadConfig { seed: cfg.seed, ..Default::default() };
            lc.threads = runner::resolve_threads(args.usize("threads", 1)?);
            if let Some(loads) = args.str_opt("loads") {
                lc.loads_rps = loads
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<f64>().map_err(|_| {
                            Error::Config(format!("--loads: `{s}` is not a number"))
                        })
                    })
                    .collect::<Result<_>>()?;
            }
            lc.requests_per_point = sweep_requests(args, "load-requests", lc.requests_per_point)?;
            (Some(lc), None)
        }
    } else {
        (None, None)
    };
    let fleet_closed = which == "fleet" && args.bool("closed-loop");
    let fleet_closed_cfg = if fleet_closed {
        // --telemetry switches the sweep into the drift-telemetry
        // configuration: same scenario, control-loop sampler on, pinned
        // to the contended K=32 point, telemetry_drift.json output.
        let mut fc = if args.bool("telemetry") {
            fleet::telemetry_config(cfg.seed)
        } else {
            fleet::FleetClosedConfig { seed: cfg.seed, ..Default::default() }
        };
        fc.threads = runner::resolve_threads(args.usize("threads", 1)?);
        if args.str_opt("shapes").is_some() {
            return Err(Error::Config(
                "--shapes does not apply to the closed-loop fleet sweep (one \
                 topology per run; use --topology for a custom one)"
                    .into(),
            ));
        }
        if args.str_opt("offered-rps").is_some() {
            return Err(Error::Config(
                "--offered-rps does not apply to the closed-loop fleet sweep \
                 (arrivals are generated by completions)"
                    .into(),
            ));
        }
        if let Some(path) = args.str_opt("topology") {
            fc.topo = cnmt::fleet::Topology::load(&PathBuf::from(path))?;
        }
        if let Some(clients) = args.str_opt("clients") {
            fc.clients = clients
                .split(',')
                .map(|s| {
                    s.trim().parse::<usize>().map_err(|_| {
                        Error::Config(format!("--clients: `{s}` is not an integer"))
                    })
                })
                .collect::<Result<_>>()?;
        }
        fc.think_s = args.f64("think-ms", 0.0)? / 1e3;
        fc.requests_per_point = sweep_requests(args, "fleet-requests", fc.requests_per_point)?;
        Some(fc)
    } else {
        None
    };
    let fleet_cfg = if matches!(which.as_str(), "fleet" | "all") && !fleet_closed {
        let mut fc = fleet::FleetConfig { seed: cfg.seed, ..Default::default() };
        fc.threads = runner::resolve_threads(args.usize("threads", 1)?);
        if let Some(path) = args.str_opt("topology") {
            if args.str_opt("shapes").is_some() {
                return Err(Error::Config(
                    "--topology and --shapes are mutually exclusive (a custom \
                     spec replaces the preset grid)"
                        .into(),
                ));
            }
            let topo = cnmt::fleet::Topology::load(&PathBuf::from(path))?;
            let offered_rps = args.f64("offered-rps", 96.0)?;
            fc.shapes = vec![fleet::ShapeSpec { topo, offered_rps }];
        } else {
            // The presets carry tuned loads; silently dropping an
            // explicit --offered-rps would sweep at a load the user
            // never asked for.
            if args.str_opt("offered-rps").is_some() {
                return Err(Error::Config(
                    "--offered-rps only applies with --topology (the preset \
                     shapes carry tuned offered loads)"
                        .into(),
                ));
            }
            if let Some(shapes) = args.str_opt("shapes") {
                fc.shapes = shapes
                    .split(',')
                    .map(|s| {
                        let topo = cnmt::fleet::Topology::preset(s.trim())?;
                        let offered_rps = fleet::default_offered_rps(&topo);
                        Ok(fleet::ShapeSpec { topo, offered_rps })
                    })
                    .collect::<Result<_>>()?;
            }
        }
        fc.requests_per_point = sweep_requests(args, "fleet-requests", fc.requests_per_point)?;
        Some(fc)
    } else {
        None
    };
    let outage_cfg = if matches!(which.as_str(), "outage" | "all") {
        let mut oc = outage::OutageConfig { seed: cfg.seed, ..Default::default() };
        oc.threads = runner::resolve_threads(args.usize("threads", 1)?);
        oc.requests_per_point = sweep_requests(args, "outage-requests", oc.requests_per_point)?;
        // Opt-in gauge sampling (satellite of the detection work): off
        // by default so the checked-in outage_sweep.json bytes never
        // move. Only the dedicated run consumes the flag — on `all` it
        // stays unknown and is rejected below.
        if which == "outage" && args.bool("telemetry") {
            oc.opts.telemetry = Some(cnmt::obs::TelemetryCfg::default());
        }
        Some(oc)
    } else {
        None
    };
    let detect_cfg = if matches!(which.as_str(), "detect" | "all") {
        let mut dc = detect::DetectConfig::default();
        dc.base.seed = cfg.seed;
        dc.base.threads = runner::resolve_threads(args.usize("threads", 1)?);
        dc.base.requests_per_point =
            sweep_requests(args, "detect-requests", dc.base.requests_per_point)?;
        Some(dc)
    } else {
        None
    };
    // `--scenario spec.json` is accepted by every experiment subcommand:
    // the named spec replays (fifo baseline vs edf) after the requested
    // experiment; `cnmt experiment scenario` runs it standalone with the
    // checked-in default spec when the flag is absent.
    let scenario_path = args.str_opt("scenario");
    let scenario_cfg = if matches!(which.as_str(), "scenario" | "all") || scenario_path.is_some()
    {
        let mut sc = scenario::ScenarioConfig::default();
        if let Some(path) = scenario_path.as_deref() {
            sc.spec = cnmt::sim::ScenarioSpec::load(&PathBuf::from(path))?;
        }
        sc.threads = runner::resolve_threads(args.usize("threads", 1)?);
        // The unified --requests overrides the spec's request count
        // like every other sweep; the seed stays the spec's (a scenario
        // is a named, reproducible artifact).
        sc.spec.requests = args.usize("requests", sc.spec.requests)?;
        Some(sc)
    } else {
        None
    };
    // The decision-log leg only exists on the dedicated outage run; on
    // `all` a stray --trace stays unknown and is rejected below.
    let outage_trace = if which == "outage" { args.str_opt("trace") } else { None };
    args.reject_unknown()?;

    let run_fig2a = |cfg: &Config| -> Result<()> {
        let f = fig2a::run(LangPair::EnZh, &cal, samples, cfg.seed)?;
        print!("{}", fig2a::render_text(&f));
        let p = report::write_report(&cfg.out_dir, "fig2a", &fig2a::to_json(&f))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };
    let run_fig3 = |cfg: &Config| -> Result<()> {
        let f = fig3::run(samples, cfg.seed)?;
        print!("{}", fig3::render_text(&f));
        let p = report::write_report(&cfg.out_dir, "fig3", &fig3::to_json(&f))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };
    let run_fig4 = |cfg: &Config| -> Result<()> {
        let f = fig4::run(cfg.seed)?;
        print!("{}", fig4::render_text(&f));
        fig4::write_traces(&f, &cfg.out_dir)?;
        let p = report::write_report(&cfg.out_dir, "fig4", &fig4::to_json(&f))?;
        eprintln!("wrote {} (+ trace CSVs)\n", p.display());
        Ok(())
    };
    let run_table1 = |cfg: &Config| -> Result<()> {
        eprintln!(
            "table1: {} requests x {} pairs x {} profiles (seed {})",
            cfg.requests,
            cfg.pairs.len(),
            cfg.profiles.len(),
            cfg.seed
        );
        let t = table1::run(cfg, &cal)?;
        print!("{}", table1::render_text(&t));
        let p = report::write_report(&cfg.out_dir, "table1", &table1::to_json(&t))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_ablation = |cfg: &Config| -> Result<()> {
        eprintln!("ablation: estimator zoo over the Table-I grid...");
        let a = ablation::run(cfg, &cal)?;
        print!("{}", ablation::render_text(&a));
        let p = report::write_report(&cfg.out_dir, "ablation", &ablation::to_json(&a))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_energy = |cfg: &Config| -> Result<()> {
        eprintln!("energy: gateway-energy view of the policy grid...");
        let e = energy::run(cfg, &cal, cnmt::devices::EnergyModel::default())?;
        print!("{}", energy::render_text(&e));
        let p = report::write_report(&cfg.out_dir, "energy", &energy::to_json(&e))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_load = |cfg: &Config| -> Result<()> {
        if let Some(closed_cfg) = closed_cfg.as_ref() {
            eprintln!(
                "load (closed-loop): {} requests/point over {} client counts (seed {})",
                closed_cfg.requests_per_point,
                closed_cfg.clients.len(),
                closed_cfg.seed
            );
            let s = load::run_closed(closed_cfg)?;
            print!("{}", load::render_closed_text(&s));
            let p =
                report::write_report(&cfg.out_dir, "closed_loop", &load::closed_to_json(&s))?;
            eprintln!("wrote {}\n", p.display());
            return Ok(());
        }
        let load_cfg = load_cfg.as_ref().expect("load_cfg built for load/all");
        eprintln!(
            "load: {} requests/point over {} offered loads (seed {})",
            load_cfg.requests_per_point,
            load_cfg.loads_rps.len(),
            load_cfg.seed
        );
        let s = load::run(load_cfg)?;
        print!("{}", load::render_text(&s));
        let p = report::write_report(&cfg.out_dir, "load_sweep", &load::to_json(&s))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_fleet_exp = |cfg: &Config| -> Result<()> {
        if let Some(fc) = fleet_closed_cfg.as_ref() {
            let telemetry = fc.opts.telemetry.is_some();
            eprintln!(
                "fleet (closed-loop{}): {} requests/cell over {} client counts on \
                 `{}` (seed {})",
                if telemetry { ", telemetry" } else { "" },
                fc.requests_per_point,
                fc.clients.len(),
                fc.topo.name,
                fc.seed
            );
            let s = fleet::run_closed(fc)?;
            let (name, text, json) = if telemetry {
                (
                    "telemetry_drift",
                    fleet::render_telemetry_text(&s),
                    fleet::telemetry_to_json(&s),
                )
            } else {
                (
                    "fleet_closed_loop",
                    fleet::render_closed_text(&s),
                    fleet::closed_to_json(&s),
                )
            };
            print!("{text}");
            let p = report::write_report(&cfg.out_dir, name, &json)?;
            eprintln!("wrote {}\n", p.display());
            return Ok(());
        }
        let fleet_cfg = fleet_cfg.as_ref().expect("fleet_cfg built for fleet/all");
        eprintln!(
            "fleet: {} requests/cell over {} shapes (seed {})",
            fleet_cfg.requests_per_point,
            fleet_cfg.shapes.len(),
            fleet_cfg.seed
        );
        let s = fleet::run(fleet_cfg)?;
        print!("{}", fleet::render_text(&s));
        let p = report::write_report(&cfg.out_dir, "fleet_sweep", &fleet::to_json(&s))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_outage = |cfg: &Config| -> Result<()> {
        let oc = outage_cfg.as_ref().expect("outage_cfg built for outage/all");
        eprintln!(
            "outage: {} requests/cell, mid-run edge-gateway crash on `{}` \
             (baseline vs failover, seed {})",
            oc.requests_per_point, oc.topo.name, oc.seed
        );
        let s = outage::run(oc)?;
        print!("{}", outage::render_text(&s));
        let p = report::write_report(&cfg.out_dir, "outage_sweep", &outage::to_json(&s))?;
        eprintln!("wrote {}\n", p.display());
        if let Some(trace_path) = outage_trace.as_deref() {
            let out = PathBuf::from(trace_path);
            if let Some(parent) = out.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            let sink = std::io::BufWriter::new(std::fs::File::create(&out)?);
            // The ring is only a live window; the sink carries the full
            // stream, which is what the offline verifier needs.
            let rec = cnmt::obs::FlightRecorder::new(4096).with_sink(Box::new(sink));
            let (pool, ch) = outage::outage_pool(oc);
            let fault =
                outage::outage_fault_spec(&oc.topo, oc.requests_per_point, oc.offered_rps);
            let (res, mut rec) = cnmt::sim::run_fleet_outage_traced(
                &pool, &ch, &oc.topo, &oc.opts, &fault, &oc.retry, true, rec,
            )?;
            // finish() appends the health trailer before the flush.
            rec.finish();
            if !rec.sink_ok() {
                return Err(Error::Config(format!(
                    "outage trace: write to {} failed",
                    out.display()
                )));
            }
            eprintln!(
                "dumped {} failover-cell events to {} ({} admitted: {} completed, \
                 {} reroutes, {} retries, {} timeouts)\n",
                rec.total(),
                out.display(),
                res.admitted,
                res.completed,
                res.failover_reroutes,
                res.retry_dispatches,
                res.timeouts_fired
            );
        }
        Ok(())
    };

    let run_detect = |cfg: &Config| -> Result<()> {
        let dc = detect_cfg.as_ref().expect("detect_cfg built for detect/all");
        eprintln!(
            "detect: {} requests/scenario, 5 scenarios (twin/crash/slow/link/\
             surge) on `{}` under the online detector (seed {})",
            dc.base.requests_per_point, dc.base.topo.name, dc.base.seed
        );
        let e = detect::run(dc)?;
        print!("{}", detect::render_text(&e));
        let p = report::write_report(&cfg.out_dir, "detect_eval", &detect::to_json(&e))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_scenario_exp = |cfg: &Config| -> Result<()> {
        let sc = scenario_cfg.as_ref().expect("scenario_cfg built when requested");
        eprintln!(
            "scenario `{}`: {} requests on `{}` (fifo baseline vs edf + \
             class-aware hedging, seed {})",
            sc.spec.name, sc.spec.requests, sc.spec.topology, sc.spec.seed
        );
        let s = scenario::run(sc)?;
        print!("{}", scenario::render_text(&s));
        let p =
            report::write_report(&cfg.out_dir, "scenario_sweep", &scenario::to_json(&s))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_multilevel = |cfg: &Config| -> Result<()> {
        eprintln!("multilevel: 3-tier CI (end-device/gateway/cloud)...");
        let m = multilevel::run(cfg, &cal)?;
        print!("{}", multilevel::render_text(&m));
        let p = report::write_report(&cfg.out_dir, "multilevel", &multilevel::to_json(&m))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    match which.as_str() {
        "fig2a" => run_fig2a(&cfg)?,
        "fig3" => run_fig3(&cfg)?,
        "fig4" => run_fig4(&cfg)?,
        "table1" => run_table1(&cfg)?,
        "ablation" => run_ablation(&cfg)?,
        "energy" => run_energy(&cfg)?,
        "multilevel" => run_multilevel(&cfg)?,
        "load" => run_load(&cfg)?,
        "fleet" => run_fleet_exp(&cfg)?,
        "outage" => run_outage(&cfg)?,
        "detect" => run_detect(&cfg)?,
        "scenario" => run_scenario_exp(&cfg)?,
        "all" => {
            run_fig4(&cfg)?;
            run_fig3(&cfg)?;
            run_fig2a(&cfg)?;
            run_table1(&cfg)?;
            run_ablation(&cfg)?;
            run_energy(&cfg)?;
            run_multilevel(&cfg)?;
            run_load(&cfg)?;
            run_fleet_exp(&cfg)?;
            run_outage(&cfg)?;
            run_detect(&cfg)?;
            run_scenario_exp(&cfg)?;
        }
        other => return Err(Error::Config(format!("unknown experiment `{other}`"))),
    }
    // A --scenario passed to another subcommand rides along after it.
    if !matches!(which.as_str(), "scenario" | "all") && scenario_cfg.is_some() {
        run_scenario_exp(&cfg)?;
    }
    Ok(())
}

/// Ground-truth executor over a synthetic workload: a batch costs its
/// longest member plus a residual of the rest (the same cost model the
/// contended harness charges).
struct SynthExec<'a> {
    truths: &'a [cnmt::sim::harness::RequestTruth],
    residual: f64,
}

impl cnmt::scheduler::BatchExecutor for SynthExec<'_> {
    fn execute(
        &mut self,
        device: cnmt::devices::DeviceKind,
        batch: &[cnmt::scheduler::QueuedRequest],
        _start_s: f64,
    ) -> f64 {
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for rq in batch {
            let truth = &self.truths[rq.payload];
            let t = match device {
                cnmt::devices::DeviceKind::Edge => truth.t_edge,
                cnmt::devices::DeviceKind::Cloud => truth.t_cloud,
            };
            max = max.max(t);
            sum += t;
        }
        max + (sum - max) * self.residual
    }
}

/// The dispatcher surface the event-loop bench drives — implemented by
/// the zero-churn [`cnmt::scheduler::Dispatcher`] and the frozen
/// pre-rewrite [`cnmt::scheduler::BaselineDispatcher`], so both run the
/// identical stream in the same binary and the reported speedup is a
/// same-container measurement.
trait BenchDispatch {
    fn drain(&mut self, horizon_s: f64, exec: &mut SynthExec<'_>, completions: &mut u64);
    fn wait(&self, device: cnmt::devices::DeviceKind, now_s: f64) -> f64;
    fn put(&mut self, device: cnmt::devices::DeviceKind, rq: cnmt::scheduler::QueuedRequest);
    fn put_hedged(&mut self, rq: cnmt::scheduler::QueuedRequest, e: f64, c: f64);
    fn batches(&self) -> u64;
}

impl BenchDispatch for cnmt::scheduler::Dispatcher {
    fn drain(&mut self, horizon_s: f64, exec: &mut SynthExec<'_>, completions: &mut u64) {
        self.run_until(horizon_s, exec, &mut |_c| *completions += 1);
    }
    fn wait(&self, device: cnmt::devices::DeviceKind, now_s: f64) -> f64 {
        self.expected_wait_s(device, now_s)
    }
    fn put(&mut self, device: cnmt::devices::DeviceKind, rq: cnmt::scheduler::QueuedRequest) {
        self.submit(device, rq);
    }
    fn put_hedged(&mut self, rq: cnmt::scheduler::QueuedRequest, e: f64, c: f64) {
        self.submit_hedged(rq, e, c);
    }
    fn batches(&self) -> u64 {
        self.batch_stats().batches
    }
}

impl BenchDispatch for cnmt::scheduler::BaselineDispatcher {
    fn drain(&mut self, horizon_s: f64, exec: &mut SynthExec<'_>, completions: &mut u64) {
        self.run_until(horizon_s, exec, &mut |_c| *completions += 1);
    }
    fn wait(&self, device: cnmt::devices::DeviceKind, now_s: f64) -> f64 {
        self.expected_wait_s(device, now_s)
    }
    fn put(&mut self, device: cnmt::devices::DeviceKind, rq: cnmt::scheduler::QueuedRequest) {
        self.submit(device, rq);
    }
    fn put_hedged(&mut self, rq: cnmt::scheduler::QueuedRequest, e: f64, c: f64) {
        self.submit_hedged(rq, e, c);
    }
    fn batches(&self) -> u64 {
        self.batch_stats().batches
    }
}

/// Drive the full per-request cycle (route → submit → event loop) over
/// a synthetic stream and count dispatcher events (batch starts +
/// completion events). `hedge_margin_s` > 0 exercises the hedged path.
/// Returns `(events, wall_seconds)`.
fn bench_event_loop<D: BenchDispatch>(
    disp: &mut D,
    requests: usize,
    offered_rps: f64,
    hedge_margin_s: f64,
) -> (u64, f64) {
    use cnmt::coordinator::{PolicyKind, RouterBuilder};
    use cnmt::devices::DeviceKind;
    use cnmt::experiments::load::{
        synth_workload, CLOUD_PLANE, EDGE_PLANE, N2M_DELTA, N2M_GAMMA, RTT_S,
    };
    use cnmt::predictor::{N2mRegressor, TexeModel};
    use cnmt::scheduler::QueuedRequest;

    let (truths, _ch) = synth_workload(0xBE7C5, requests, offered_rps);
    let mut router = RouterBuilder::new(PolicyKind::Cnmt)
        .texe(
            TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2),
            TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2),
        )
        .n2m(N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA))
        .ttx(0.3, RTT_S)
        .build()
        .expect("bench router");
    router.observe_ttx(0.0, RTT_S);
    let n2m = N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA);
    let mut exec = SynthExec { truths: &truths, residual: 0.15 };
    let mut completions = 0u64;
    let t0 = std::time::Instant::now();
    for (i, truth) in truths.iter().enumerate() {
        let now = truth.arrival_s;
        disp.drain(now, &mut exec, &mut completions);
        let edge_wait = disp.wait(DeviceKind::Edge, now);
        let cloud_wait = disp.wait(DeviceKind::Cloud, now);
        let trace = router.decide_loaded(truth.n, edge_wait, cloud_wait);
        let queued = QueuedRequest {
            id: i as u64,
            payload: i,
            n: truth.n,
            m_est: n2m.predict(truth.n),
            est_service_s: 0.0,
            arrival_s: now,
            bucket: 0,
            hedge: None,
        };
        let margin = trace.loaded_margin_s(edge_wait, cloud_wait);
        if hedge_margin_s > 0.0 && margin.is_finite() && margin.abs() <= hedge_margin_s {
            disp.put_hedged(queued, trace.t_edge_est, trace.t_cloud_est);
        } else {
            let mut queued = queued;
            queued.est_service_s = match trace.device {
                DeviceKind::Edge => trace.t_edge_est,
                DeviceKind::Cloud => trace.t_cloud_est,
            };
            disp.put(trace.device, queued);
        }
    }
    disp.drain(f64::INFINITY, &mut exec, &mut completions);
    let wall_s = t0.elapsed().as_secs_f64();
    (completions + disp.batches(), wall_s)
}

/// Per-lane ground-truth executor for the fleet event-loop bench
/// (tier time × the device's slowdown; batch = max + residual·rest).
/// A deliberate bench-local stand-in, not the harness's
/// `FleetExecutor`: the bench measures event-loop throughput, so its
/// cost law only needs to be *plausible*, not in lockstep with the
/// product/mirror ground truth (no drift, fixed residual).
struct FleetSynthExec<'a> {
    truths: &'a [cnmt::sim::harness::RequestTruth],
    tier: Vec<cnmt::devices::DeviceKind>,
    slowdown: Vec<f64>,
    residual: f64,
}

impl cnmt::scheduler::LaneExecutor for FleetSynthExec<'_> {
    fn execute_lane(
        &mut self,
        lane: usize,
        _device: cnmt::devices::DeviceKind,
        batch: &[cnmt::scheduler::QueuedRequest],
        _start_s: f64,
    ) -> f64 {
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for rq in batch {
            let truth = &self.truths[rq.payload];
            let base = match self.tier[lane] {
                cnmt::devices::DeviceKind::Edge => truth.t_edge,
                cnmt::devices::DeviceKind::Cloud => truth.t_cloud,
            };
            let t = base * self.slowdown[lane];
            max = max.max(t);
            sum += t;
        }
        max + (sum - max) * self.residual
    }
}

/// Drive the fleet path's full per-request cycle (selector arg-min →
/// submit_lane → N-lane event loop) over a synthetic stream and count
/// dispatcher events — the same definition [`bench_event_loop`] uses
/// for the pair path, so the two are directly comparable. Returns
/// `(events, wall_seconds)`.
fn bench_fleet_loop(
    topo: &cnmt::fleet::Topology,
    requests: usize,
    offered_rps: f64,
) -> (u64, f64) {
    use cnmt::experiments::load::{
        synth_workload, CLOUD_PLANE, EDGE_PLANE, N2M_DELTA, N2M_GAMMA, RTT_S,
    };
    use cnmt::fleet::FleetSelector;
    use cnmt::predictor::{N2mRegressor, TexeModel};
    use cnmt::scheduler::{BatchPolicy, Dispatcher, QueuedRequest};

    let (truths, _ch) = synth_workload(0xBE7C5, requests, offered_rps);
    let mut sel = FleetSelector::new(
        topo,
        TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2),
        TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2),
        N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA),
    )
    .expect("bench fleet selector");
    sel.observe_ttx(0.0, RTT_S);
    let n_dev = topo.len();
    let mut disp = Dispatcher::with_lanes(&topo.lane_specs(512), BatchPolicy::default());
    let mut exec = FleetSynthExec {
        truths: &truths,
        tier: topo.devices.iter().map(|d| d.tier).collect(),
        slowdown: topo.devices.iter().map(|d| d.slowdown()).collect(),
        residual: 0.15,
    };
    let mut waits = vec![0.0f64; n_dev];
    let mut completions = 0u64;
    let t0 = std::time::Instant::now();
    for (i, truth) in truths.iter().enumerate() {
        let now = truth.arrival_s;
        disp.run_until(now, &mut exec, &mut |_c| completions += 1);
        for (d, w) in waits.iter_mut().enumerate() {
            *w = disp.expected_wait_lane(d, now);
        }
        let trace = sel.select(truth.n, &waits);
        disp.submit_lane(
            trace.device,
            QueuedRequest {
                id: i as u64,
                payload: i,
                n: truth.n,
                m_est: trace.m_est,
                est_service_s: trace.est_service_s,
                arrival_s: now,
                bucket: 0,
                hedge: None,
            },
        );
    }
    disp.run_until(f64::INFINITY, &mut exec, &mut |_c| completions += 1);
    let wall_s = t0.elapsed().as_secs_f64();
    (completions + disp.batch_stats().batches, wall_s)
}

/// Best-of-3 fleet event-loop measurement on one topology.
fn fleet_loop_json(
    label: &str,
    topo: &cnmt::fleet::Topology,
    requests: usize,
    offered_rps: f64,
) -> cnmt::util::Json {
    use cnmt::util::Json;
    let mut best: Option<(u64, f64)> = None;
    for _ in 0..3 {
        let (events, wall_s) = bench_fleet_loop(topo, requests, offered_rps);
        best = Some(match best {
            Some((e, w)) if w <= wall_s => (e, w),
            _ => (events, wall_s),
        });
    }
    let (events, wall_s) = best.expect("three samples taken");
    let eps = events as f64 / wall_s;
    eprintln!(
        "  {label:<18} {events} events in {wall_s:.3} s  →  {eps:.0} events/s \
         ({:.0} ns/event)",
        1e9 / eps
    );
    let mut o = Json::object();
    o.set("topology", Json::Str(topo.name.clone()))
        .set("lanes", Json::Num(topo.len() as f64))
        .set("requests", Json::Num(requests as f64))
        .set("offered_rps", Json::Num(offered_rps))
        .set("events", Json::Num(events as f64))
        .set("wall_s", Json::Num(wall_s))
        .set("events_per_sec", Json::Num(eps))
        .set("ns_per_event", Json::Num(1e9 / eps));
    o
}

/// The fleet event-loop cycle of [`bench_fleet_loop`] with the failure
/// machinery armed: deadline timers enabled, one `arm_timeout` per
/// admitted request (deadline = the default [`RetryPolicy`] over the
/// scored estimate) and a due-timer sweep + selector re-route before
/// every arrival — the exact per-request overhead the outage harness
/// pays. No fault is injected, so timers almost never fire and the
/// measured delta is the bookkeeping cost itself (heap push + armed-map
/// insert + lazy disarm), which CI gates as a ratio against the untimed
/// loop (bench_gate.py --min-failover-ratio).
///
/// [`RetryPolicy`]: cnmt::scheduler::RetryPolicy
fn bench_fleet_failover_loop(
    topo: &cnmt::fleet::Topology,
    requests: usize,
    offered_rps: f64,
) -> (u64, f64) {
    use cnmt::experiments::load::{
        synth_workload, CLOUD_PLANE, EDGE_PLANE, N2M_DELTA, N2M_GAMMA, RTT_S,
    };
    use cnmt::fleet::FleetSelector;
    use cnmt::predictor::{N2mRegressor, TexeModel};
    use cnmt::scheduler::{BatchPolicy, Dispatcher, QueuedRequest, RetryPolicy};

    let (truths, _ch) = synth_workload(0xBE7C5, requests, offered_rps);
    let retry = RetryPolicy::default();
    let mut sel = FleetSelector::new(
        topo,
        TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2),
        TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2),
        N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA),
    )
    .expect("bench fleet selector");
    sel.observe_ttx(0.0, RTT_S);
    let n_dev = topo.len();
    let mut disp = Dispatcher::with_lanes(&topo.lane_specs(512), BatchPolicy::default());
    disp.enable_timers();
    let mut exec = FleetSynthExec {
        truths: &truths,
        tier: topo.devices.iter().map(|d| d.tier).collect(),
        slowdown: topo.devices.iter().map(|d| d.slowdown()).collect(),
        residual: 0.15,
    };
    let mut waits = vec![0.0f64; n_dev];
    let mut fired = Vec::new();
    let mut completions = 0u64;
    let t0 = std::time::Instant::now();
    for (i, truth) in truths.iter().enumerate() {
        let now = truth.arrival_s;
        disp.fire_timeouts(now, &mut fired);
        disp.run_until(now, &mut exec, &mut |_c| completions += 1);
        // Re-route anything a deadline pulled out (rare without a
        // fault, but the path has to be live to be measured).
        while let Some(rq) = fired.pop() {
            let id = rq.id;
            for (d, w) in waits.iter_mut().enumerate() {
                *w = disp.expected_wait_lane(d, now);
            }
            let trace = sel.select(rq.n, &waits);
            let admitted = disp.submit_lane(
                trace.device,
                QueuedRequest { est_service_s: trace.est_service_s, ..rq },
            );
            if admitted.is_admitted() {
                disp.arm_timeout(
                    id,
                    trace.device,
                    now + retry.deadline_after(trace.est_service_s),
                );
            }
        }
        for (d, w) in waits.iter_mut().enumerate() {
            *w = disp.expected_wait_lane(d, now);
        }
        let trace = sel.select(truth.n, &waits);
        let admitted = disp.submit_lane(
            trace.device,
            QueuedRequest {
                id: i as u64,
                payload: i,
                n: truth.n,
                m_est: trace.m_est,
                est_service_s: trace.est_service_s,
                arrival_s: now,
                bucket: 0,
                hedge: None,
            },
        );
        if admitted.is_admitted() {
            disp.arm_timeout(
                i as u64,
                trace.device,
                now + retry.deadline_after(trace.est_service_s),
            );
        }
    }
    disp.run_until(f64::INFINITY, &mut exec, &mut |_c| completions += 1);
    let wall_s = t0.elapsed().as_secs_f64();
    (completions + disp.batch_stats().batches, wall_s)
}

/// Best-of-3 failover-armed fleet event-loop measurement.
fn fleet_failover_json(
    label: &str,
    topo: &cnmt::fleet::Topology,
    requests: usize,
    offered_rps: f64,
) -> cnmt::util::Json {
    use cnmt::util::Json;
    let mut best: Option<(u64, f64)> = None;
    for _ in 0..3 {
        let (events, wall_s) = bench_fleet_failover_loop(topo, requests, offered_rps);
        best = Some(match best {
            Some((e, w)) if w <= wall_s => (e, w),
            _ => (events, wall_s),
        });
    }
    let (events, wall_s) = best.expect("three samples taken");
    let eps = events as f64 / wall_s;
    eprintln!(
        "  {label:<18} {events} events in {wall_s:.3} s  →  {eps:.0} events/s \
         ({:.0} ns/event)",
        1e9 / eps
    );
    let mut o = Json::object();
    o.set("topology", Json::Str(topo.name.clone()))
        .set("lanes", Json::Num(topo.len() as f64))
        .set("requests", Json::Num(requests as f64))
        .set("offered_rps", Json::Num(offered_rps))
        .set("events", Json::Num(events as f64))
        .set("wall_s", Json::Num(wall_s))
        .set("events_per_sec", Json::Num(eps))
        .set("ns_per_event", Json::Num(1e9 / eps));
    o
}

/// Best-of-3 event-loop measurement for one dispatcher implementation.
fn event_loop_json<D: BenchDispatch>(
    label: &str,
    mk: impl Fn() -> D,
    requests: usize,
    hedge_margin_s: f64,
) -> cnmt::util::Json {
    use cnmt::util::Json;
    let mut best: Option<(u64, f64)> = None;
    for _ in 0..3 {
        let mut disp = mk();
        let (events, wall_s) = bench_event_loop(&mut disp, requests, 96.0, hedge_margin_s);
        best = Some(match best {
            Some((e, w)) if w <= wall_s => (e, w),
            _ => (events, wall_s),
        });
    }
    let (events, wall_s) = best.expect("three samples taken");
    let eps = events as f64 / wall_s;
    eprintln!(
        "  {label:<18} {events} events in {wall_s:.3} s  →  {eps:.0} events/s \
         ({:.0} ns/event)",
        1e9 / eps
    );
    let mut o = Json::object();
    o.set("requests", Json::Num(requests as f64))
        .set("hedge_margin_s", Json::Num(hedge_margin_s))
        .set("events", Json::Num(events as f64))
        .set("wall_s", Json::Num(wall_s))
        .set("events_per_sec", Json::Num(eps))
        .set("ns_per_event", Json::Num(1e9 / eps));
    o
}

/// Records per trace-codec measurement inside `cnmt bench sched`
/// (the standalone `cnmt bench trace` takes `--requests`).
const TRACE_BENCH_RECORDS: usize = 100_000;

/// Best-of-3 trace-codec measurement: encode the synthetic scenario to
/// an in-memory buffer, decode it back, and report both sides in the
/// same events/sec unit the event-loop benches use. CI gates the
/// decode rate (`bench_gate.py --min-trace-events`).
fn trace_codec_json(records: usize) -> Result<cnmt::util::Json> {
    use cnmt::trace::{record_synth, SynthSpec, TraceReader};
    use cnmt::util::Json;

    let spec = SynthSpec {
        seed: 0xBE7C7,
        requests: records,
        offered_rps: 96.0,
        exec_noise_std: 0.0,
    };
    let mut bytes = Vec::new();
    let mut enc_s = f64::INFINITY;
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        let (_, b) = record_synth(&spec, Vec::new())?;
        enc_s = enc_s.min(t0.elapsed().as_secs_f64());
        bytes = b;
    }
    let mut dec_s = f64::INFINITY;
    let mut decoded = 0u64;
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        let mut n = 0u64;
        for rec in TraceReader::open(std::io::Cursor::new(&bytes))? {
            rec?;
            n += 1;
        }
        dec_s = dec_s.min(t0.elapsed().as_secs_f64());
        decoded = n;
    }
    let side = |events: u64, wall_s: f64| {
        let eps = events as f64 / wall_s;
        let mut o = Json::object();
        o.set("events", Json::Num(events as f64))
            .set("wall_s", Json::Num(wall_s))
            .set("events_per_sec", Json::Num(eps))
            .set("ns_per_event", Json::Num(1e9 / eps));
        o
    };
    eprintln!(
        "  trace codec: {records} records, {} bytes ({:.2} B/record)  →  \
         encode {:.0} events/s, decode {:.0} events/s",
        bytes.len(),
        bytes.len() as f64 / records.max(1) as f64,
        records as f64 / enc_s,
        decoded as f64 / dec_s
    );
    let mut o = Json::object();
    o.set("records", Json::Num(records as f64))
        .set("bytes", Json::Num(bytes.len() as f64))
        .set(
            "bytes_per_record",
            Json::Num(bytes.len() as f64 / records.max(1) as f64),
        )
        .set("encode", side(records as u64, enc_s))
        .set("decode", side(decoded, dec_s));
    Ok(o)
}

/// `cnmt bench sched [--json] [--out p] [--requests n] [--sweep-requests n]
/// [--threads n]` — the scheduler-core perf report behind
/// `BENCH_sched.json` (events/sec, ns/event, full-sweep wall-clock at 1
/// vs N threads) — and `cnmt bench trace [--json]`, the standalone
/// trace-codec measurement. CI gates on these numbers; see
/// `.github/workflows`.
fn cmd_bench(args: &Args) -> Result<()> {
    use cnmt::util::bench::{bench, BenchConfig};
    use cnmt::util::Json;

    let which = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "sched".to_string());
    if which == "trace" {
        let out_flag = args.str_opt("out");
        let write_json = args.bool("json") || out_flag.is_some();
        let out = PathBuf::from(
            out_flag.unwrap_or_else(|| "reports/BENCH_trace.json".to_string()),
        );
        let records = args.usize("requests", TRACE_BENCH_RECORDS)?;
        args.reject_unknown()?;
        if records == 0 {
            return Err(Error::Config("bench trace needs --requests > 0".into()));
        }
        eprintln!("bench trace: codec over {records} in-memory records");
        let section = trace_codec_json(records)?;
        let mut root = Json::object();
        root.set("schema", Json::Str("bench_trace/v1".into()))
            .set("producer", Json::Str("cnmt bench trace".into()))
            .set("trace", section);
        if write_json {
            let path = report::write_report(
                out.parent().unwrap_or_else(|| std::path::Path::new(".")),
                out.file_stem().and_then(|s| s.to_str()).unwrap_or("BENCH_trace"),
                &root,
            )?;
            eprintln!("wrote {}", path.display());
        }
        return Ok(());
    }
    if which != "sched" {
        return Err(Error::Config(format!(
            "unknown bench target `{which}` (try `cnmt bench sched` or \
             `cnmt bench trace`)"
        )));
    }
    // An explicit --out implies --json: dropping a requested output
    // path on the floor would be silent data loss.
    let out_flag = args.str_opt("out");
    let write_json = args.bool("json") || out_flag.is_some();
    let out = PathBuf::from(
        out_flag.unwrap_or_else(|| "reports/BENCH_sched.json".to_string()),
    );
    let requests = args.usize("requests", 20_000)?;
    let sweep_requests = args.usize("sweep-requests", 4_000)?;
    let threads = runner::resolve_threads(args.usize("threads", 0)?);
    args.reject_unknown()?;

    use cnmt::scheduler::{BaselineDispatcher, Dispatcher, DispatcherConfig};
    eprintln!("bench sched: event loop over {requests} requests (dense vs frozen baseline)");
    let mk_dense = || Dispatcher::new(&DispatcherConfig::default());
    let mk_base = || BaselineDispatcher::new(&DispatcherConfig::default());
    let solo = event_loop_json("solo/dense", mk_dense, requests, 0.0);
    let solo_base = event_loop_json("solo/baseline", mk_base, requests, 0.0);
    let hedged = event_loop_json("hedged/dense", mk_dense, requests, 0.010);
    let hedged_base = event_loop_json("hedged/baseline", mk_base, requests, 0.010);
    let speedup_solo = solo.get("events_per_sec").unwrap().as_f64().unwrap()
        / solo_base.get("events_per_sec").unwrap().as_f64().unwrap();
    let speedup_hedged = hedged.get("events_per_sec").unwrap().as_f64().unwrap()
        / hedged_base.get("events_per_sec").unwrap().as_f64().unwrap();
    eprintln!(
        "  speedup vs pre-rewrite baseline: {speedup_solo:.2}x solo, \
         {speedup_hedged:.2}x hedged"
    );

    // Flight-recorder overhead: the identical hedged stream with a
    // bounded ring (no sink) attached to the dispatcher — the per-event
    // cost of the decision log. CI gates the ratio (bench_gate.py
    // --min-recorder-ratio).
    const RECORDER_BENCH_CAPACITY: usize = 4096;
    let mk_rec = || {
        let mut d = Dispatcher::new(&DispatcherConfig::default());
        d.attach_recorder(cnmt::obs::FlightRecorder::new(RECORDER_BENCH_CAPACITY));
        d
    };
    let hedged_rec = event_loop_json("hedged/dense+rec", mk_rec, requests, 0.010);
    let hedged_eps = hedged.get("events_per_sec").unwrap().as_f64().unwrap();
    let recorder_ratio =
        hedged_rec.get("events_per_sec").unwrap().as_f64().unwrap() / hedged_eps;
    eprintln!(
        "  flight recorder on the hedged path: {recorder_ratio:.2}x events/sec \
         (ring capacity {RECORDER_BENCH_CAPACITY}, no sink)"
    );

    // Detector overhead: the identical hedged stream with the online
    // anomaly detector tapping every completion's execution residual —
    // the steady-state cost of self-diagnosis. CI gates the ratio
    // (bench_gate.py --min-detect-ratio).
    let mk_det = || {
        use cnmt::devices::DeviceKind;
        let mut d = Dispatcher::new(&DispatcherConfig::default());
        d.attach_detector(cnmt::obs::Detector::new(
            &[DeviceKind::Edge, DeviceKind::Cloud],
            cnmt::obs::DetectCfg::default(),
        ));
        d
    };
    let hedged_det = event_loop_json("hedged/dense+det", mk_det, requests, 0.010);
    let detect_ratio =
        hedged_det.get("events_per_sec").unwrap().as_f64().unwrap() / hedged_eps;
    eprintln!(
        "  anomaly detector on the hedged path: {detect_ratio:.2}x events/sec \
         (CUSUM residual charts, no recorder)"
    );

    // Fleet path: the same per-request cycle through the FleetSelector
    // + N-lane surface, on the pair shape (lane-generalisation overhead
    // vs the classic pair path — gated) and a 6-lane scale-up
    // (informational).
    eprintln!("bench sched: fleet event loop (selector + N-lane surface)");
    let topo_pair = cnmt::fleet::Topology::pair();
    let topo_4x2 = cnmt::fleet::Topology::preset("4x2").expect("built-in preset");
    let fleet_lane2 = fleet_loop_json("fleet/1x1", &topo_pair, requests, 96.0);
    let fleet_lane6 = fleet_loop_json("fleet/4x2", &topo_4x2, requests, 288.0);
    let fleet_ratio = fleet_lane2.get("events_per_sec").unwrap().as_f64().unwrap()
        / solo.get("events_per_sec").unwrap().as_f64().unwrap();
    eprintln!(
        "  fleet 1x1 path runs at {:.2}x the classic pair path's events/sec",
        fleet_ratio
    );

    // Failover overhead: the identical fleet cycle on the outage
    // topology with the failure machinery armed — deadline timer per
    // admitted request + due-timer sweep per arrival. CI gates the
    // ratio (bench_gate.py --min-failover-ratio).
    eprintln!("bench sched: failover-armed fleet loop (deadline timers, hetero)");
    let topo_hetero = cnmt::fleet::Topology::hetero();
    let fleet_hetero = fleet_loop_json("fleet/hetero", &topo_hetero, requests, 224.0);
    let failover_hetero =
        fleet_failover_json("failover/hetero", &topo_hetero, requests, 224.0);
    let failover_ratio = failover_hetero
        .get("events_per_sec")
        .unwrap()
        .as_f64()
        .unwrap()
        / fleet_hetero.get("events_per_sec").unwrap().as_f64().unwrap();
    eprintln!(
        "  timers armed on every request cost {:.2}x events/sec vs the untimed \
         loop",
        failover_ratio
    );

    // Scenario replay: the full SLO-class engine (fair EDF front-end,
    // class-aware hedge bar, batch-aware waits) vs the identical storm
    // through class-blind FIFO lanes — the pay-for-use cost of the
    // service-class machinery on the default scenario. CI gates the
    // ratio (bench_gate.py --min-scenario-ratio).
    eprintln!("bench sched: scenario replay (SLO classes, fifo vs edf)");
    let (scenario_fifo, scenario_edf, scenario_ratio) = {
        use cnmt::experiments::load::synth_shaped_workload;
        use cnmt::experiments::scenario::default_scenario_spec;
        use cnmt::sim::{
            run_scenario_engine, FleetOpts, HedgeShape, ScenarioSpec, Scheduling,
        };
        let mut spec = default_scenario_spec();
        spec.requests = requests;
        let topo = spec.topology()?;
        spec.validate_for(&topo)?;
        let (truths, ch) = synth_shaped_workload(spec.seed, spec.requests, &spec.load);
        let opts = FleetOpts::default();
        let mut fifo_spec = spec.clone();
        fifo_spec.scheduling = Scheduling::Fifo;
        fifo_spec.hedge =
            fifo_spec.hedge.map(|h| HedgeShape { class_aware: false, ..h });
        let mut measure = |label: &str, s: &ScenarioSpec| -> Result<Json> {
            let mut best: Option<(usize, f64)> = None;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                let (res, _rec) =
                    run_scenario_engine(&truths, &ch, &topo, &opts, s, None)?;
                let wall_s = t0.elapsed().as_secs_f64();
                best = Some(match best {
                    Some((c, w)) if w <= wall_s => (c, w),
                    _ => (res.completed, wall_s),
                });
            }
            let (completed, wall_s) = best.expect("three samples taken");
            let rps = truths.len() as f64 / wall_s;
            eprintln!(
                "  {label:<18} {} requests in {wall_s:.3} s  →  {rps:.0} \
                 requests/s",
                truths.len()
            );
            let mut o = Json::object();
            o.set("scheduling", Json::Str(s.scheduling.tag().to_string()))
                .set("requests", Json::Num(truths.len() as f64))
                .set("completed", Json::Num(completed as f64))
                .set("wall_s", Json::Num(wall_s))
                .set("requests_per_sec", Json::Num(rps));
            Ok(o)
        };
        let fifo = measure("scenario/fifo", &fifo_spec)?;
        let edf = measure("scenario/edf", &spec)?;
        let ratio = edf.get("requests_per_sec").unwrap().as_f64().unwrap()
            / fifo.get("requests_per_sec").unwrap().as_f64().unwrap();
        eprintln!(
            "  EDF + class machinery runs at {ratio:.2}x the class-blind FIFO \
             replay's requests/sec"
        );
        (fifo, edf, ratio)
    };

    // Hot-path latency: the full steady-state per-request cycle.
    let hot = {
        use cnmt::devices::DeviceKind;
        use cnmt::experiments::load::synth_workload;
        use cnmt::scheduler::{Dispatcher, DispatcherConfig, QueuedRequest};
        let (truths, ch) = synth_workload(0xBE7C6, 2_048, 96.0);
        let mut router = cnmt::coordinator::RouterBuilder::new(
            cnmt::coordinator::PolicyKind::Cnmt,
        )
        .texe(ch.texe_edge, ch.texe_cloud)
        .n2m(ch.n2m)
        .build()
        .expect("bench router");
        router.observe_ttx(0.0, 0.042);
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut i = 0usize;
        let mut t = 0.0f64;
        let mut id = 0u64;
        bench("enqueue_decide_dispatch", BenchConfig::fast(), move || {
            // The executor is two words of plain data; rebuilding it per
            // iteration sidesteps a self-borrow of the moved `truths`.
            let mut exec = SynthExec { truths: &truths, residual: 0.15 };
            i = (i + 1) & 2047;
            t += 1e-4;
            disp.run_until(t, &mut exec, &mut |_c| {});
            let ew = disp.expected_wait_s(DeviceKind::Edge, t);
            let cw = disp.expected_wait_s(DeviceKind::Cloud, t);
            let trace = router.decide_loaded(truths[i].n, ew, cw);
            id += 1;
            disp.submit(
                trace.device,
                QueuedRequest {
                    id,
                    payload: i,
                    n: truths[i].n,
                    m_est: trace.m_est,
                    est_service_s: match trace.device {
                        DeviceKind::Edge => trace.t_edge_est,
                        DeviceKind::Cloud => trace.t_cloud_est,
                    },
                    arrival_s: t,
                    bucket: 0,
                    hedge: None,
                },
            )
        })
    };
    eprintln!(
        "  hot path {:.0} ns/request (p95 {:.0} ns)",
        hot.mean_ns, hot.p95_ns
    );

    // Trace codec: encode/decode throughput of the binary workload
    // trace format, so a replay-heavy CI run can be budgeted.
    eprintln!("bench sched: trace codec ({TRACE_BENCH_RECORDS} records in memory)");
    let trace_section = trace_codec_json(TRACE_BENCH_RECORDS)?;

    // Full-parameter-shaped sweep wall-clock, serial vs sharded.
    eprintln!("bench sched: sweep wall-clock ({sweep_requests} requests/point)");
    let mut sweep_cfg = load::LoadConfig {
        requests_per_point: sweep_requests,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let serial_sweep = load::run(&sweep_cfg)?;
    let serial_s = t0.elapsed().as_secs_f64();
    sweep_cfg.threads = threads;
    let t0 = std::time::Instant::now();
    let parallel_sweep = load::run(&sweep_cfg)?;
    let parallel_s = t0.elapsed().as_secs_f64();
    // Determinism spot-check rides along with every bench run.
    let same = load::to_json(&serial_sweep).to_string_pretty()
        == load::to_json(&parallel_sweep).to_string_pretty();
    if !same {
        return Err(Error::Sim(
            "parallel sweep diverged from serial sweep (determinism bug)".into(),
        ));
    }
    let speedup = serial_s / parallel_s;
    eprintln!(
        "  sweep: {:.2} s serial → {:.2} s at {threads} threads  ({speedup:.2}x, \
         bit-identical)",
        serial_s, parallel_s
    );

    // Cell count derived from the actual sweep result (configurations
    // per point × points + drift cells), not hardcoded.
    let cells = serial_sweep
        .cells
        .iter()
        .map(|c| c.results.len())
        .sum::<usize>()
        + serial_sweep.drift.results.len();
    let mut sweep = Json::object();
    sweep
        .set("requests_per_point", Json::Num(sweep_requests as f64))
        .set("cells", Json::Num(cells as f64))
        .set("threads", Json::Num(threads as f64))
        .set("serial_wall_s", Json::Num(serial_s))
        .set("parallel_wall_s", Json::Num(parallel_s))
        .set("speedup", Json::Num(speedup))
        .set("bit_identical", Json::Bool(same));
    let mut baseline = Json::object();
    baseline
        .set(
            "structures",
            Json::Str(
                "pre-rewrite dispatcher (scheduler::baseline): VecDeque queues, \
                 id-keyed HashMap hedges + HashSet cancel tokens, per-batch Vec \
                 allocation, uncached earliest-free scan"
                    .into(),
            ),
        )
        .set("event_loop_solo", solo_base)
        .set("event_loop_hedged", hedged_base);
    let mut speedup = Json::object();
    speedup
        .set("event_loop_solo", Json::Num(speedup_solo))
        .set("event_loop_hedged", Json::Num(speedup_hedged));
    let mut fleet_section = Json::object();
    fleet_section
        .set("lane2", fleet_lane2)
        .set("lane6", fleet_lane6)
        .set("ratio_vs_pair_solo", Json::Num(fleet_ratio));
    let mut failover_section = Json::object();
    failover_section
        .set("untimed", fleet_hetero)
        .set("armed", failover_hetero)
        .set("ratio", Json::Num(failover_ratio));
    let mut recorder_section = Json::object();
    recorder_section
        .set("capacity", Json::Num(RECORDER_BENCH_CAPACITY as f64))
        .set("disabled_events_per_sec", Json::Num(hedged_eps))
        .set("enabled", hedged_rec)
        .set("ratio", Json::Num(recorder_ratio));
    let mut detector_section = Json::object();
    detector_section
        .set("disabled_events_per_sec", Json::Num(hedged_eps))
        .set("enabled", hedged_det)
        .set("ratio", Json::Num(detect_ratio));
    let mut scenario_section = Json::object();
    scenario_section
        .set("fifo", scenario_fifo)
        .set("edf", scenario_edf)
        .set("ratio", Json::Num(scenario_ratio));
    let mut root = Json::object();
    root.set("schema", Json::Str("bench_sched/v1".into()))
        .set("producer", Json::Str("cnmt bench sched".into()))
        .set("event_loop_solo", solo)
        .set("event_loop_hedged", hedged)
        .set("fleet", fleet_section)
        .set("failover", failover_section)
        .set("hot_path", hot.to_json())
        .set("sweep", sweep)
        .set("baseline", baseline)
        .set("speedup", speedup)
        .set("recorder", recorder_section)
        .set("detector", detector_section)
        .set("scenario", scenario_section)
        .set("trace", trace_section);
    if write_json {
        let path = report::write_report(
            out.parent().unwrap_or_else(|| std::path::Path::new(".")),
            out.file_stem().and_then(|s| s.to_str()).unwrap_or("BENCH_sched"),
            &root,
        )?;
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

/// `cnmt trace dump|summary|verify|record|replay|info` — trace tooling.
///
/// The first three operate on the `obs` flight recorder's decision log:
/// `dump` streams a complete JSONL trace from a canned hedged-adaptive
/// contended pair replay (every admission, placement scoring, batch,
/// dispatch, completion, hedge cancellation, refit install, margin
/// adjustment and drift tick); `summary` counts a dumped trace by event
/// tag; `verify` replays it through the offline checker, re-proving
/// conservation, hedge-fate partitioning, the margin control law and
/// waste-budget compliance from the log alone.
///
/// The last three operate on binary *workload* traces (`.ctr`,
/// [`cnmt::trace`]): `record` captures the synthetic scenario once,
/// `replay` streams it back through the contended harness under four
/// policies in O(outstanding) memory, and `info` validates + summarizes
/// a trace file.
fn cmd_trace(args: &Args) -> Result<()> {
    use cnmt::obs::{summarize_trace, verify_trace, FlightRecorder};

    let action = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "help".to_string());
    match action.as_str() {
        "dump" => {
            let out = PathBuf::from(args.str("out", "trace.jsonl"));
            let requests = args.usize("requests", 2_000)?;
            let load = args.f64("load", 120.0)?;
            let seed = args.u64("seed", 20220315)?;
            args.reject_unknown()?;
            if requests == 0 {
                return Err(Error::Config("trace dump needs --requests > 0".into()));
            }
            if !(load.is_finite() && load > 0.0) {
                return Err(Error::Config(format!(
                    "trace dump load {load} must be finite and > 0"
                )));
            }
            use cnmt::experiments::load::synth_workload;
            let (truths, ch) = synth_workload(seed, requests, load);
            let opts = cnmt::sim::ContentionOpts {
                adaptive: Some(cnmt::sim::AdaptiveOpts::default()),
                ..Default::default()
            };
            if let Some(parent) = out.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            let sink = std::io::BufWriter::new(std::fs::File::create(&out)?);
            // The ring is only a live window; the sink carries the full
            // stream, which is what the verifier needs.
            let rec = FlightRecorder::new(4096).with_sink(Box::new(sink));
            let (res, mut rec) = cnmt::sim::run_contended_traced(
                &truths,
                &ch,
                cnmt::coordinator::PolicyKind::Cnmt,
                &opts,
                rec,
            )?;
            // finish() appends the health trailer (event count, ring
            // evictions, sink status) before the final flush.
            rec.finish();
            if !rec.sink_ok() {
                return Err(Error::Config(format!(
                    "trace dump: write to {} failed",
                    out.display()
                )));
            }
            eprintln!(
                "dumped {} events to {} ({} offered: {} completed, {} shed, \
                 {} hedged)",
                rec.total(),
                out.display(),
                res.offered,
                res.completed,
                res.rejected,
                res.hedged
            );
            Ok(())
        }
        "summary" | "verify" => {
            let path = args.positional.get(2).cloned().ok_or_else(|| {
                Error::Config(format!("`cnmt trace {action}` needs a trace file"))
            })?;
            // Only verify downgrades truncation; on summary the flag
            // stays unknown and is rejected below.
            let allow_truncated =
                action == "verify" && args.bool("allow-truncated");
            args.reject_unknown()?;
            let text = std::fs::read_to_string(&path)?;
            if action == "summary" {
                println!("{}", summarize_trace(&text)?.to_string_pretty());
            } else {
                let r = if allow_truncated {
                    cnmt::obs::verify_trace_allow_truncated(&text)?
                } else {
                    verify_trace(&text)?
                };
                println!("{}", r.to_json().to_string_pretty());
                if r.dropped_prefix > 0 {
                    eprintln!(
                        "trace verify OK (truncated window: {} leading events \
                         dropped — local checks and tallies only)",
                        r.dropped_prefix
                    );
                } else {
                    eprintln!(
                        "trace verify OK: {} events — conservation ({} results for \
                         {} admitted), hedge-fate partition ({} hedged) and \
                         waste-budget compliance re-proven offline",
                        r.events, r.results, r.admitted, r.hedged
                    );
                }
            }
            Ok(())
        }
        "record" => {
            let out = PathBuf::from(args.str("out", "trace.ctr"));
            let requests = args.usize("requests", 100_000)?;
            let load = args.f64("load", 96.0)?;
            let seed = args.u64("seed", 20220315)?;
            let exec_noise = args.f64("exec-noise", 0.0)?;
            args.reject_unknown()?;
            if requests == 0 {
                return Err(Error::Config("trace record needs --requests > 0".into()));
            }
            if !(load.is_finite() && load > 0.0) {
                return Err(Error::Config(format!(
                    "trace record load {load} must be finite and > 0"
                )));
            }
            if !(exec_noise.is_finite() && exec_noise >= 0.0) {
                return Err(Error::Config(format!(
                    "trace record exec-noise {exec_noise} must be finite and >= 0"
                )));
            }
            let spec = cnmt::trace::SynthSpec {
                seed,
                requests,
                offered_rps: load,
                exec_noise_std: exec_noise,
            };
            if let Some(parent) = out.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            let sink = std::io::BufWriter::new(std::fs::File::create(&out)?);
            let (header, sink) = cnmt::trace::record_synth(&spec, sink)?;
            drop(sink);
            let bytes = std::fs::metadata(&out)?.len();
            eprintln!(
                "recorded {requests} requests to {} ({bytes} bytes, {} mode, \
                 seed {seed}, {load} r/s offered)",
                out.display(),
                if header.times_explicit() { "explicit-times" } else { "derived" }
            );
            Ok(())
        }
        "replay" => {
            let path = args.positional.get(2).cloned().ok_or_else(|| {
                Error::Config("`cnmt trace replay` needs a trace file".into())
            })?;
            let out_dir = PathBuf::from(args.str("out", "reports"));
            let threads = runner::resolve_threads(args.usize("threads", 1)?);
            args.reject_unknown()?;
            use cnmt::util::{Json, JsonStream};
            // One validating pass up front: every block CRC and the end
            // marker are checked before any cell burns simulation time.
            let summary = cnmt::trace::summarize(std::io::BufReader::new(
                std::fs::File::open(&path)?,
            ))?;
            eprintln!(
                "replaying {} records ({:.1} r/s offered) through 4 policy \
                 cells at {threads} threads",
                summary.records, summary.offered_rps
            );
            use cnmt::coordinator::PolicyKind;
            let configs: [(PolicyKind, bool, bool); 4] = [
                (PolicyKind::EdgeOnly, false, false),
                (PolicyKind::CloudOnly, false, false),
                (PolicyKind::Cnmt, true, false),
                (PolicyKind::Cnmt, true, true),
            ];
            let path = &path;
            let outcomes = runner::run_cells(threads, configs.len(), |cell| {
                let (policy, queue_aware, adaptive) = configs[cell];
                // Each cell re-opens the file: no shared decode state,
                // so the cells stay pure functions of the cell index.
                let reader = cnmt::trace::TraceReader::open(std::io::BufReader::new(
                    std::fs::File::open(path)?,
                ))?;
                let ch = reader.header().characterization();
                let opts = cnmt::sim::ContentionOpts {
                    queue_aware,
                    adaptive: if adaptive {
                        Some(cnmt::sim::AdaptiveOpts::default())
                    } else {
                        None
                    },
                    ..Default::default()
                };
                cnmt::sim::run_contended_streamed(reader, &ch, policy, &opts)
            });
            let mut results = Vec::with_capacity(configs.len());
            for outcome in outcomes {
                results.push(outcome?);
            }
            for r in &results {
                eprintln!(
                    "  {:<18} completed {}/{}  mean {:.1} ms  p99 {:.1} ms",
                    r.policy,
                    r.completed,
                    r.offered,
                    r.mean_latency_s * 1e3,
                    r.p99_s * 1e3
                );
            }
            std::fs::create_dir_all(&out_dir)?;
            let out_path = out_dir.join("trace_replay.json");
            let mut s = JsonStream::new(std::io::BufWriter::new(std::fs::File::create(
                &out_path,
            )?));
            s.begin_object();
            s.key("cells");
            s.begin_array();
            for r in &results {
                s.value(&r.to_json());
            }
            s.end_array();
            s.key("producer");
            s.value(&Json::Str("cnmt trace replay".into()));
            s.key("records");
            s.value(&Json::Num(summary.records as f64));
            s.key("schema");
            s.value(&Json::Str("trace_replay/v1".into()));
            s.end_object();
            s.finish()?;
            eprintln!("wrote {}", out_path.display());
            Ok(())
        }
        "info" => {
            let path = args.positional.get(2).cloned().ok_or_else(|| {
                Error::Config("`cnmt trace info` needs a trace file".into())
            })?;
            args.reject_unknown()?;
            use cnmt::util::Json;
            let s = cnmt::trace::summarize(std::io::BufReader::new(std::fs::File::open(
                &path,
            )?))?;
            let mut o = Json::object();
            o.set("records", Json::Num(s.records as f64))
                .set("version", Json::Num(s.version as f64))
                .set("times_explicit", Json::Bool(s.times_explicit))
                .set("duration_s", Json::Num(s.duration_s))
                .set("offered_rps", Json::Num(s.offered_rps))
                .set("mean_n", Json::Num(s.mean_n))
                .set("mean_m", Json::Num(s.mean_m));
            println!("{}", o.to_string_pretty());
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown trace action `{other}` (try dump, summary, verify, \
             record, replay or info)"
        ))),
    }
}

/// Stubs for the PJRT-backed commands when built without the `pjrt`
/// feature (the default: the offline environment has no XLA library).
#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> Result<()> {
    Err(Error::Config(format!(
        "`cnmt {cmd}` needs the real PJRT runtime — rebuild with \
         `--features pjrt`"
    )))
}

#[cfg(not(feature = "pjrt"))]
fn cmd_calibrate(_args: &Args) -> Result<()> {
    pjrt_unavailable("calibrate")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_translate(_args: &Args) -> Result<()> {
    pjrt_unavailable("translate")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_selfcheck(_args: &Args) -> Result<()> {
    pjrt_unavailable("selfcheck")
}

/// Real-PJRT characterisation: measure translations over an (N, M) grid
/// per model, fit the T_exe planes, derive edge/cloud device models.
#[cfg(feature = "pjrt")]
fn cmd_calibrate(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    let out = PathBuf::from(args.str("out", "artifacts/calibration.json"));
    let samples = args.usize("samples", 120)?;
    let edge_slowdown = args.f64("edge-slowdown", 1.0)?;
    let cloud_speedup = args.f64("cloud-speedup", 5.0)?;
    let models_filter = args.str("models", "");
    let seed = args.u64("seed", 7)?;
    args.reject_unknown()?;

    let manifest = ArtifactManifest::load(&artifacts)?;
    let mut rng = cnmt::util::Rng::new(seed);
    let mut all_samples = std::collections::BTreeMap::new();
    for model in &manifest.models {
        if !models_filter.is_empty()
            && !models_filter.split(',').any(|m| m == model.name)
        {
            continue;
        }
        eprintln!("calibrating {} ({samples} translations)...", model.name);
        let engine = Seq2SeqEngine::from_manifest(&manifest, &model.name)?;
        // Warm up (first executions pay one-time lazy initialisation).
        let warm: Vec<u16> = vec![7; 8];
        for _ in 0..3 {
            engine.translate(
                &warm,
                TranslateOptions { force_steps: Some(4), ..Default::default() },
            )?;
        }
        let mut sm = Vec::with_capacity(samples);
        for i in 0..samples {
            let n = 1 + rng.usize(manifest.n_max - 2);
            let m = 1 + rng.usize(manifest.m_max - 2);
            let src: Vec<u16> = (0..n).map(|_| 3 + rng.usize(4093) as u16).collect();
            let tr = engine.translate(
                &src,
                TranslateOptions { force_steps: Some(m), ..Default::default() },
            )?;
            sm.push((n as f64, m as f64, tr.total_s()));
            if (i + 1) % 40 == 0 {
                eprintln!("  {}/{samples}", i + 1);
            }
        }
        all_samples.insert(model.name.clone(), sm);
    }
    let cal = Calibration::from_measurements(&all_samples, edge_slowdown, cloud_speedup)?;
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    cal.save(&out)?;
    eprintln!("wrote {}", out.display());
    for model in cal.models() {
        for dev in cnmt::devices::DeviceKind::ALL {
            let tm = cal.get(dev, &model)?;
            eprintln!(
                "  {}/{}: aN={:.3}ms aM={:.3}ms b={:.3}ms (r2 {:.3})",
                dev.id(),
                model,
                tm.texe.alpha_n * 1e3,
                tm.texe.alpha_m * 1e3,
                tm.texe.beta * 1e3,
                tm.texe.r2,
            );
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_translate(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    let model = args.str_req("model")?;
    let ids_flag = args.str_opt("ids");
    let text_flag = args.str_opt("text");
    let max_steps = args.usize("max-steps", 64)?;
    args.reject_unknown()?;

    let tok = Tokenizer::new(4096);
    let src: Vec<u16> = match (ids_flag, text_flag) {
        (Some(ids), _) => ids
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u16>()
                    .map_err(|_| Error::Config(format!("bad token id `{s}`")))
            })
            .collect::<Result<_>>()?,
        (None, Some(text)) => tok.tokenize(&text)?,
        (None, None) => {
            return Err(Error::Config("need --ids or --text".into()));
        }
    };
    let engine = Seq2SeqEngine::load(&artifacts, &model)?;
    let tr = engine.translate(
        &src,
        TranslateOptions { max_steps: Some(max_steps), ..Default::default() },
    )?;
    println!("source ({} tokens): {}", src.len(), tok.detokenize(&src));
    let out_u16: Vec<u16> = tr.tokens.iter().map(|&t| t as u16).collect();
    println!("output ({} steps):  {}", tr.steps, tok.detokenize(&out_u16));
    println!(
        "encode {:.2} ms, decode {:.2} ms ({:.2} ms/token)",
        tr.encode_s * 1e3,
        tr.decode_s * 1e3,
        tr.decode_s * 1e3 / tr.steps.max(1) as f64
    );
    Ok(())
}

/// Load + execute every artifact; verifies determinism and reports a
/// per-model latency sketch. This is the post-`make artifacts` sanity
/// gate.
#[cfg(feature = "pjrt")]
fn cmd_selfcheck(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    args.reject_unknown()?;
    let manifest = ArtifactManifest::load(&artifacts)?;
    let mut summary = Json::object();
    for model in &manifest.models {
        eprintln!("== {}", model.name);
        let engine = Seq2SeqEngine::from_manifest(&manifest, &model.name)?;
        let src: Vec<u16> = vec![10, 17, 23, 99, 5];
        let opts = TranslateOptions { force_steps: Some(8), ..Default::default() };
        let a = engine.translate(&src, opts)?;
        let b = engine.translate(&src, opts)?;
        if a.tokens != b.tokens {
            return Err(Error::Serve(format!(
                "{}: nondeterministic decode",
                model.name
            )));
        }
        let long: Vec<u16> = (100..160).collect();
        let c = engine.translate(
            &long,
            TranslateOptions { force_steps: Some(30), ..Default::default() },
        )?;
        eprintln!(
            "   n=5 m=8: enc {:.2}ms dec {:.2}ms | n=60 m=30: enc {:.2}ms dec {:.2}ms",
            a.encode_s * 1e3,
            a.decode_s * 1e3,
            c.encode_s * 1e3,
            c.decode_s * 1e3
        );
        let mut o = Json::object();
        o.set("dec_ms_per_step", Json::Num(c.decode_s * 1e3 / 30.0));
        summary.set(&model.name, o);
    }
    println!("selfcheck OK: {}", summary.to_string());
    Ok(())
}
