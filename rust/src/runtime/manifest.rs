//! `artifacts/manifest.json` parsing — the contract between the python
//! AOT path and the rust runtime (see `python/compile/model.py` for the
//! authoritative description of the decode-input wiring).

use std::path::{Path, PathBuf};

use crate::util::Json;
use crate::{Error, Result};

/// Tensor dtype tags used in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(Error::Artifact(format!("unknown dtype `{other}`"))),
        }
    }

    pub fn size(self) -> usize {
        4
    }

    pub fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        }
    }
}

/// One weight tensor's layout inside the weights blob.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub offset: usize,
    pub nbytes: usize,
}

/// Shape+dtype of one encoder output.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// How the initial value of a decode-loop state input is produced.
#[derive(Debug, Clone)]
pub enum StateInit {
    /// Seed from encoder output `idx`.
    FromEncoder(usize),
    /// Zero tensor of the given shape/dtype.
    Zeros(Vec<usize>, DType),
}

/// Source of one decode-step input (python `DecodeInput`).
#[derive(Debug, Clone)]
pub enum DecodeInputSpec {
    /// Encoder output `idx`, constant across decode steps.
    Encoder(usize),
    /// The source-length scalar.
    Length,
    /// Loop state `idx`: fed from decode output `idx + 1`.
    State { idx: usize, init: StateInit },
    /// The previous target token.
    Token,
}

/// Everything needed to run one model.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub lang_pair: String,
    pub arch: String,
    pub encode_hlo: PathBuf,
    pub decode_hlo: PathBuf,
    pub weights_bin: PathBuf,
    pub weights_sha256: String,
    pub params: Vec<ParamMeta>,
    pub encode_outputs: Vec<TensorMeta>,
    pub decode_inputs: Vec<DecodeInputSpec>,
    pub n_state: usize,
}

impl ModelManifest {
    /// Total bytes the weights blob must have.
    pub fn weights_len(&self) -> usize {
        self.params.iter().map(|p| p.nbytes).sum()
    }

    /// Index (within decode inputs) of the token slot.
    pub fn token_slot(&self) -> Result<usize> {
        self.decode_inputs
            .iter()
            .position(|d| matches!(d, DecodeInputSpec::Token))
            .ok_or_else(|| Error::Artifact(format!("{}: no token slot", self.name)))
    }

    pub fn validate(&self) -> Result<()> {
        // Param layout must be dense and in-order.
        let mut expect = 0usize;
        for p in &self.params {
            if p.offset != expect {
                return Err(Error::Artifact(format!(
                    "{}: param {} at offset {} (expected {expect})",
                    self.name, p.name, p.offset
                )));
            }
            let elems: usize = p.shape.iter().product::<usize>().max(1);
            if elems * p.dtype.size() != p.nbytes {
                return Err(Error::Artifact(format!(
                    "{}: param {} shape/nbytes mismatch",
                    self.name, p.name
                )));
            }
            expect += p.nbytes;
        }
        // State indices dense, one token slot, enc indices in range.
        let mut state_idx: Vec<usize> = Vec::new();
        let mut token_slots = 0usize;
        for d in &self.decode_inputs {
            match d {
                DecodeInputSpec::State { idx, init } => {
                    state_idx.push(*idx);
                    if let StateInit::FromEncoder(i) = init {
                        if *i >= self.encode_outputs.len() {
                            return Err(Error::Artifact(format!(
                                "{}: state init enc idx {i} out of range",
                                self.name
                            )));
                        }
                    }
                }
                DecodeInputSpec::Encoder(i) => {
                    if *i >= self.encode_outputs.len() {
                        return Err(Error::Artifact(format!(
                            "{}: enc idx {i} out of range",
                            self.name
                        )));
                    }
                }
                DecodeInputSpec::Token => token_slots += 1,
                DecodeInputSpec::Length => {}
            }
        }
        state_idx.sort_unstable();
        if state_idx != (0..self.n_state).collect::<Vec<_>>() {
            return Err(Error::Artifact(format!(
                "{}: state indices not dense: {state_idx:?}",
                self.name
            )));
        }
        if token_slots != 1 {
            return Err(Error::Artifact(format!(
                "{}: expected 1 token slot, got {token_slots}",
                self.name
            )));
        }
        Ok(())
    }
}

/// The whole artifacts directory.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub n_max: usize,
    pub m_max: usize,
    pub vocab: usize,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub models: Vec<ModelManifest>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<ArtifactManifest> {
        let models = j
            .get("models")?
            .as_array()?
            .iter()
            .map(|m| parse_model(dir, m))
            .collect::<Result<Vec<_>>>()?;
        let man = ArtifactManifest {
            dir: dir.to_path_buf(),
            seed: j.get("seed")?.as_i64()? as u64,
            n_max: j.get("n_max")?.as_usize()?,
            m_max: j.get("m_max")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            pad_id: j.get("pad_id")?.as_i64()? as i32,
            bos_id: j.get("bos_id")?.as_i64()? as i32,
            eos_id: j.get("eos_id")?.as_i64()? as i32,
            models,
        };
        for m in &man.models {
            m.validate()?;
        }
        Ok(man)
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| Error::Artifact(format!("model `{name}` not in manifest")))
    }
}

fn parse_tensor_meta(j: &Json) -> Result<TensorMeta> {
    Ok(TensorMeta {
        shape: j.get("shape")?.as_shape()?,
        dtype: DType::parse(j.get("dtype")?.as_str()?)?,
    })
}

fn parse_state_init(j: &Json) -> Result<StateInit> {
    match j.get("kind")?.as_str()? {
        "enc" => Ok(StateInit::FromEncoder(j.get("idx")?.as_usize()?)),
        "zeros" => Ok(StateInit::Zeros(
            j.get("shape")?.as_shape()?,
            DType::parse(j.get("dtype")?.as_str()?)?,
        )),
        other => Err(Error::Artifact(format!("bad state init kind `{other}`"))),
    }
}

fn parse_decode_input(j: &Json) -> Result<DecodeInputSpec> {
    match j.get("kind")?.as_str()? {
        "enc" => Ok(DecodeInputSpec::Encoder(j.get("idx")?.as_usize()?)),
        "length" => Ok(DecodeInputSpec::Length),
        "token" => Ok(DecodeInputSpec::Token),
        "state" => Ok(DecodeInputSpec::State {
            idx: j.get("idx")?.as_usize()?,
            init: parse_state_init(j.get("init")?)?,
        }),
        other => Err(Error::Artifact(format!("bad decode input kind `{other}`"))),
    }
}

fn parse_model(dir: &Path, j: &Json) -> Result<ModelManifest> {
    let params = j
        .get("params")?
        .as_array()?
        .iter()
        .map(|p| {
            Ok(ParamMeta {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p.get("shape")?.as_shape()?,
                dtype: DType::parse(p.get("dtype")?.as_str()?)?,
                offset: p.get("offset")?.as_usize()?,
                nbytes: p.get("nbytes")?.as_usize()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelManifest {
        name: j.get("name")?.as_str()?.to_string(),
        lang_pair: j.get("lang_pair")?.as_str()?.to_string(),
        arch: j.get("arch")?.as_str()?.to_string(),
        encode_hlo: dir.join(j.get("encode_hlo")?.as_str()?),
        decode_hlo: dir.join(j.get("decode_hlo")?.as_str()?),
        weights_bin: dir.join(j.get("weights_bin")?.as_str()?),
        weights_sha256: j.get("weights_sha256")?.as_str()?.to_string(),
        params,
        encode_outputs: j
            .get("encode_outputs")?
            .as_array()?
            .iter()
            .map(parse_tensor_meta)
            .collect::<Result<Vec<_>>>()?,
        decode_inputs: j
            .get("decode_inputs")?
            .as_array()?
            .iter()
            .map(parse_decode_input)
            .collect::<Result<Vec<_>>>()?,
        n_state: j.get("n_state")?.as_usize()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> Json {
        Json::parse(
            r#"{
              "version": 1, "seed": 1, "n_max": 64, "m_max": 64,
              "vocab": 4096, "pad_id": 0, "bos_id": 1, "eos_id": 2,
              "models": [{
                "name": "toy", "lang_pair": "de_en", "arch": "gru",
                "encode_hlo": "toy.encode.hlo.txt",
                "decode_hlo": "toy.decode.hlo.txt",
                "weights_bin": "toy.weights.bin",
                "weights_sha256": "x",
                "params": [
                  {"name": "a", "shape": [2, 3], "dtype": "f32",
                   "offset": 0, "nbytes": 24},
                  {"name": "b", "shape": [], "dtype": "i32",
                   "offset": 24, "nbytes": 4}
                ],
                "encode_outputs": [{"shape": [1, 8], "dtype": "f32"}],
                "decode_inputs": [
                  {"kind": "enc", "idx": 0},
                  {"kind": "length"},
                  {"kind": "state", "idx": 0,
                   "init": {"kind": "zeros", "shape": [1, 8], "dtype": "f32"}},
                  {"kind": "token"}
                ],
                "n_state": 1
              }]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let man =
            ArtifactManifest::from_json(Path::new("/tmp/a"), &mini_manifest_json())
                .unwrap();
        assert_eq!(man.models.len(), 1);
        let m = man.model("toy").unwrap();
        assert_eq!(m.weights_len(), 28);
        assert_eq!(m.token_slot().unwrap(), 3);
        assert_eq!(m.n_state, 1);
        assert!(man.model("missing").is_err());
    }

    #[test]
    fn rejects_sparse_param_layout() {
        let mut j = mini_manifest_json();
        // Corrupt offset of param b.
        if let Json::Object(root) = &mut j {
            let models = root.get_mut("models").unwrap();
            if let Json::Array(ms) = models {
                if let Json::Object(m) = &mut ms[0] {
                    if let Json::Array(ps) = m.get_mut("params").unwrap() {
                        ps[1].set("offset", Json::Num(100.0));
                    }
                }
            }
        }
        assert!(ArtifactManifest::from_json(Path::new("/tmp"), &j).is_err());
    }

    #[test]
    fn rejects_bad_dtype_and_kind() {
        assert!(DType::parse("f64").is_err());
        let bad = Json::parse(r#"{"kind": "wormhole"}"#).unwrap();
        assert!(parse_decode_input(&bad).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(man.models.len(), 3);
        assert_eq!(man.n_max, 64);
        for m in &man.models {
            assert!(m.encode_hlo.exists(), "{:?}", m.encode_hlo);
            assert!(m.decode_hlo.exists());
            assert_eq!(
                std::fs::metadata(&m.weights_bin).unwrap().len() as usize,
                m.weights_len()
            );
        }
    }
}
