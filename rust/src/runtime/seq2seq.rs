//! The autoregressive seq2seq engine: encode once, loop decode-step until
//! EOS — rust-driven, PJRT-executed, python-free.
//!
//! This is the request-path embodiment of the paper's cost model: one
//! encoder execution (O(N) for RNNs, ~O(1) for the Transformer) followed
//! by M strictly serial decode-step executions. The engine reports the
//! measured encode/decode split so the calibration pass can fit the
//! per-device T_exe planes from real runs.
//!
//! Perf notes (EXPERIMENTS.md §Perf):
//! * weights live on device (`execute_b`) — uploaded once, never copied
//!   into the decode loop;
//! * loop-carried state (RNN h/c, Transformer KV caches) is fed back as
//!   device buffers, not round-tripped through host literals;
//! * only the 4-byte `next_token` is synced to host each step (EOS check).

use std::path::Path;
use std::time::Instant;

use crate::runtime::client::RuntimeClient;
use crate::runtime::manifest::{
    ArtifactManifest, DType, DecodeInputSpec, ModelManifest, StateInit,
};
use crate::runtime::weights::{load_device_weights, DeviceWeights};
use crate::{Error, Result};

/// Options controlling one translation.
#[derive(Debug, Clone, Copy, Default)]
pub struct TranslateOptions {
    /// Hard cap on decode steps (defaults to the artifact's M_MAX).
    pub max_steps: Option<usize>,
    /// Run exactly this many steps, ignoring EOS — used by the
    /// characterisation pass and the experiment harness, where the
    /// ground-truth output length is dictated by the corpus pair
    /// (DESIGN.md §4: weights are untrained, so EOS timing would
    /// otherwise be arbitrary; compute cost per step is weight-agnostic).
    pub force_steps: Option<usize>,
}

/// Result of one translation.
#[derive(Debug, Clone)]
pub struct Translation {
    /// Emitted target token ids (without BOS; includes EOS if produced).
    pub tokens: Vec<i32>,
    /// Decode steps executed (= M, the paper's output length).
    pub steps: usize,
    /// Wall time of the encoder execution (seconds).
    pub encode_s: f64,
    /// Wall time of the full decode loop (seconds).
    pub decode_s: f64,
}

impl Translation {
    pub fn total_s(&self) -> f64 {
        self.encode_s + self.decode_s
    }
}

/// A loaded model: compiled encode/decode executables + device weights.
pub struct Seq2SeqEngine {
    client: RuntimeClient,
    model: ModelManifest,
    n_max: usize,
    m_max: usize,
    pad_id: i32,
    bos_id: i32,
    eos_id: i32,
    encode_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    weights: DeviceWeights,
}

impl Seq2SeqEngine {
    /// Load one model from an artifacts directory.
    pub fn load(artifacts_dir: &Path, model_name: &str) -> Result<Seq2SeqEngine> {
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        Self::from_manifest(&manifest, model_name)
    }

    /// Load from an already-parsed manifest.
    pub fn from_manifest(
        manifest: &ArtifactManifest,
        model_name: &str,
    ) -> Result<Seq2SeqEngine> {
        let model = manifest.model(model_name)?.clone();
        let client = RuntimeClient::cpu()?;
        let encode_exe = client.compile_hlo_file(&model.encode_hlo)?;
        let decode_exe = client.compile_hlo_file(&model.decode_hlo)?;
        let weights = load_device_weights(&client, &model)?;
        Ok(Seq2SeqEngine {
            client,
            model,
            n_max: manifest.n_max,
            m_max: manifest.m_max,
            pad_id: manifest.pad_id,
            bos_id: manifest.bos_id,
            eos_id: manifest.eos_id,
            encode_exe,
            decode_exe,
            weights,
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model.name
    }

    pub fn n_max(&self) -> usize {
        self.n_max
    }

    pub fn m_max(&self) -> usize {
        self.m_max
    }

    pub fn eos_id(&self) -> i32 {
        self.eos_id
    }

    pub fn weights_bytes(&self) -> usize {
        self.weights.total_bytes
    }

    /// Pad + EOS-terminate a source sentence; returns (tokens, length).
    fn prepare_source(&self, src: &[u16]) -> Result<(Vec<i32>, i32)> {
        if src.is_empty() {
            return Err(Error::Serve("empty source sentence".into()));
        }
        if src.len() + 1 > self.n_max {
            return Err(Error::Serve(format!(
                "source too long: {} tokens (max {})",
                src.len(),
                self.n_max - 1
            )));
        }
        let mut toks = vec![self.pad_id; self.n_max];
        for (i, &t) in src.iter().enumerate() {
            toks[i] = t as i32;
        }
        toks[src.len()] = self.eos_id;
        Ok((toks, (src.len() + 1) as i32))
    }

    /// Execute an executable over device buffers and untuple the result.
    ///
    /// The CPU PJRT client returns the (return_tuple=True) root as a
    /// single tuple-shaped buffer; we sync it to host and decompose. The
    /// per-leaf literals are re-uploaded only for loop-carried state.
    fn run(
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
        n_outputs: usize,
    ) -> Result<Vec<xla::Literal>> {
        let mut out = exe.execute_b(args)?;
        let replica = out
            .pop()
            .ok_or_else(|| Error::Xla("execute returned no replicas".into()))?;
        if replica.len() == n_outputs && n_outputs != 1 {
            // Backend already untupled the result.
            return replica
                .iter()
                .map(|b| Ok(b.to_literal_sync()?))
                .collect();
        }
        let first = replica
            .first()
            .ok_or_else(|| Error::Xla("execute returned no outputs".into()))?;
        let lit = first.to_literal_sync()?;
        // Single-output computations return a plain array; multi-output
        // ones return a tuple literal to decompose.
        let leaves = if lit.shape()?.is_tuple() {
            lit.to_tuple()?
        } else {
            vec![lit]
        };
        if leaves.len() != n_outputs {
            return Err(Error::Xla(format!(
                "expected {n_outputs} outputs, got {}",
                leaves.len()
            )));
        }
        Ok(leaves)
    }

    /// Run the encoder; returns (device buffers, host keepalive literals)
    /// for the encoder outputs.
    ///
    /// Lifetime note: `buffer_from_host_literal` copies asynchronously,
    /// so every uploaded literal must stay alive until a blocking call
    /// (the next `Self::run`, whose output sync transitively waits on all
    /// input copies) proves the copy finished. Keepalive vectors thread
    /// through this file for exactly that reason.
    fn run_encode(
        &self,
        tokens: &[i32],
        length: i32,
    ) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
        let tok_lit = RuntimeClient::literal_i32(&[1, self.n_max], tokens)?;
        let len_lit = RuntimeClient::literal_i32(&[], &[length])?;
        let tok_buf = self.client.to_device(&tok_lit)?;
        let len_buf = self.client.to_device(&len_lit)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            self.weights.buffers.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        // Blocks until outputs are ready => tok/len copies completed.
        let leaves = Self::run(
            &self.encode_exe,
            &args,
            self.model.encode_outputs.len(),
        )?;
        let bufs = leaves
            .iter()
            .map(|l| self.client.to_device(l))
            .collect::<Result<Vec<_>>>()?;
        Ok((bufs, leaves))
    }

    /// Initial decode-state buffers (per manifest wiring) plus their host
    /// keepalive literals.
    fn initial_states(
        &self,
        enc_outs: &[xla::PjRtBuffer],
    ) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
        let mut states: Vec<Option<xla::PjRtBuffer>> =
            (0..self.model.n_state).map(|_| None).collect();
        let mut keepalive = Vec::new();
        for spec in &self.model.decode_inputs {
            if let DecodeInputSpec::State { idx, init } = spec {
                let lit = match init {
                    StateInit::FromEncoder(i) => enc_outs[*i].to_literal_sync()?,
                    StateInit::Zeros(shape, dt) => {
                        let ty = match dt {
                            DType::F32 => xla::ElementType::F32,
                            DType::I32 => xla::ElementType::S32,
                        };
                        RuntimeClient::literal_zeros(shape, ty)?
                    }
                };
                states[*idx] = Some(self.client.to_device(&lit)?);
                keepalive.push(lit);
            }
        }
        let states = states
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.ok_or_else(|| Error::Artifact(format!("state {i} uninitialised")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok((states, keepalive))
    }

    /// Translate a source sentence.
    pub fn translate(
        &self,
        src: &[u16],
        opts: TranslateOptions,
    ) -> Result<Translation> {
        let (tokens, length) = self.prepare_source(src)?;

        let t0 = Instant::now();
        let (enc_outs, _enc_keepalive) = self.run_encode(&tokens, length)?;
        let encode_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let (mut states, init_keepalive) = self.initial_states(&enc_outs)?;
        let len_lit = RuntimeClient::literal_i32(&[], &[length])?;
        let len_buf = self.client.to_device(&len_lit)?;
        let bos_lit = RuntimeClient::literal_i32(&[1], &[self.bos_id])?;
        let mut token_buf = self.client.to_device(&bos_lit)?;
        // Literals backing the *current* state/token buffers; replaced
        // only after the next blocking run() proves their copies landed.
        let mut keepalive: Vec<xla::Literal> = init_keepalive;
        keepalive.push(bos_lit);

        let max_steps = opts
            .force_steps
            .unwrap_or_else(|| opts.max_steps.unwrap_or(self.m_max))
            .min(self.m_max);
        let n_outputs = 1 + self.model.n_state;
        let mut emitted: Vec<i32> = Vec::with_capacity(max_steps);

        for _ in 0..max_steps {
            // Assemble decode args in manifest order.
            let mut args: Vec<&xla::PjRtBuffer> =
                self.weights.buffers.iter().collect();
            for spec in &self.model.decode_inputs {
                match spec {
                    DecodeInputSpec::Encoder(i) => args.push(&enc_outs[*i]),
                    DecodeInputSpec::Length => args.push(&len_buf),
                    DecodeInputSpec::State { idx, .. } => args.push(&states[*idx]),
                    DecodeInputSpec::Token => args.push(&token_buf),
                }
            }
            // Blocks until done => previous keepalive's copies completed.
            let leaves = Self::run(&self.decode_exe, &args, n_outputs)?;
            let next_token = leaves[0].to_vec::<i32>()?[0];
            emitted.push(next_token);
            // Re-upload states + token for the next iteration.
            for (i, leaf) in leaves.iter().enumerate().skip(1) {
                states[i - 1] = self.client.to_device(leaf)?;
            }
            token_buf = self.client.to_device(&leaves[0])?;
            keepalive = leaves;
            if opts.force_steps.is_none() && next_token == self.eos_id {
                break;
            }
        }
        // The last uploads may still be in flight; force completion
        // before dropping their literals.
        for s in &states {
            let _ = s.to_literal_sync()?;
        }
        let _ = token_buf.to_literal_sync()?;
        drop(keepalive);
        let decode_s = t1.elapsed().as_secs_f64();

        Ok(Translation { steps: emitted.len(), tokens: emitted, encode_s, decode_s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn prepare_source_bounds() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let eng = Seq2SeqEngine::load(&artifacts_dir(), "gru_fr_en").unwrap();
        assert!(eng.prepare_source(&[]).is_err());
        assert!(eng.prepare_source(&vec![5u16; 64]).is_err());
        let (toks, len) = eng.prepare_source(&[10, 11, 12]).unwrap();
        assert_eq!(len, 4);
        assert_eq!(toks[3], eng.eos_id());
        assert_eq!(toks[4], 0);
        assert_eq!(toks.len(), 64);
    }
}
