//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Loads HLO **text** (see module docs on [`crate::runtime`]) and compiles
//! it into reusable [`xla::PjRtLoadedExecutable`]s. `PjRtClient` is
//! internally `Rc`-based (not `Send`), so a [`RuntimeClient`] — and every
//! engine built from it — must live on a single thread; the gateway
//! ([`crate::coordinator::gateway`]) therefore runs one executor thread
//! per device, each owning its own client (which also mirrors the real
//! deployment: one process per device).

use std::path::Path;

use crate::{Error, Result};

/// A PJRT CPU client plus compile helpers.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create a CPU client.
    pub fn cpu() -> Result<RuntimeClient> {
        Ok(RuntimeClient { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load HLO text from `path` and compile it.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        if !path.exists() {
            return Err(Error::Artifact(format!(
                "HLO file missing: {} (run `make artifacts`)",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Upload a host literal to a device buffer.
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Build an f32 literal from raw little-endian bytes.
    pub fn literal_f32(dims: &[usize], bytes: &[u8]) -> Result<xla::Literal> {
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )?)
    }

    /// Build an i32 literal from values.
    pub fn literal_i32(dims: &[usize], values: &[i32]) -> Result<xla::Literal> {
        let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            dims,
            &bytes,
        )?)
    }

    /// Zero-filled literal.
    pub fn literal_zeros(dims: &[usize], ty: xla::ElementType) -> Result<xla::Literal> {
        let elems: usize = dims.iter().product::<usize>().max(1);
        let bytes = vec![0u8; elems * ty.element_size_in_bytes()];
        Ok(xla::Literal::create_from_shape_and_untyped_data(ty, dims, &bytes)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let c = RuntimeClient::cpu().unwrap();
        assert!(c.device_count() >= 1);
        assert!(!c.platform_name().is_empty());
    }

    #[test]
    fn literals_roundtrip() {
        let lit = RuntimeClient::literal_i32(&[1, 3], &[7, 8, 9]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
        let z = RuntimeClient::literal_zeros(&[2, 2], xla::ElementType::F32).unwrap();
        assert_eq!(z.to_vec::<f32>().unwrap(), vec![0.0; 4]);
        let bytes: Vec<u8> = [1.5f32, -2.0].iter().flat_map(|v| v.to_le_bytes()).collect();
        let f = RuntimeClient::literal_f32(&[2], &bytes).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.5, -2.0]);
    }

    #[test]
    fn missing_hlo_file_is_artifact_error() {
        let c = RuntimeClient::cpu().unwrap();
        let err = c.compile_hlo_file(Path::new("/nonexistent/x.hlo.txt"));
        assert!(matches!(err, Err(Error::Artifact(_))));
    }
}
