//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and drives autoregressive seq2seq inference from rust.
//!
//! Python is **never** on the request path: `make artifacts` runs once at
//! build time; afterwards the `cnmt` binary is self-contained — it parses
//! `artifacts/manifest.json` ([`manifest`]), memory-maps the weight blobs
//! onto device buffers ([`weights`]), compiles the HLO text with the PJRT
//! CPU client ([`client`]) and loops the decode-step executable until EOS
//! ([`seq2seq`]) — the serial O(M) loop whose latency the paper models.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod manifest;
pub mod seq2seq;
pub mod weights;

pub use client::RuntimeClient;
pub use manifest::{ArtifactManifest, DecodeInputSpec, ModelManifest, ParamMeta};
pub use seq2seq::{Seq2SeqEngine, Translation, TranslateOptions};
