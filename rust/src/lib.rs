//! # C-NMT — Collaborative Inference for Neural Machine Translation
//!
//! Reproduction of *"C-NMT: A Collaborative Inference Framework for Neural
//! Machine Translation"* (Chen et al., 2022) as a three-layer
//! rust + JAX + Pallas serving stack. Start with the repository
//! `README.md` for the quickstart and `docs/ARCHITECTURE.md` for the
//! request lifecycle and module map.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the paper's contribution: an edge/cloud request
//!   router ([`coordinator`]) driven by linear execution-time models
//!   ([`predictor::texe`]), an N→M output-length regressor
//!   ([`predictor::n2m`]) and an online round-trip-time estimator
//!   ([`predictor::ttx`]); a load-aware scheduling subsystem
//!   ([`scheduler`]) — per-device admission queues, in-flight capacity
//!   tracking, length-bucketed micro-batching, a worker-pool dispatcher,
//!   hedged dispatch with cancel tokens — plus online RLS refit of the
//!   execution-time planes ([`predictor::rls`]) so routing tracks
//!   drifting hardware; a fleet abstraction ([`fleet`]) generalising
//!   the pair to N heterogeneous edge devices × M cloud replicas with
//!   fleet-wide queue-aware placement; and every substrate the
//!   evaluation needs:
//!   synthetic parallel corpora ([`corpus`]), RTT trace
//!   generation/replay ([`net`]), calibrated device models
//!   ([`devices`]), a discrete-event experiment harness ([`sim`]) and
//!   the experiment drivers ([`experiments`]) that regenerate each of
//!   the paper's tables/figures.
//! * **L2/L1 (python, build-time only)** — the three NMT models (BiLSTM,
//!   GRU, Transformer) with Pallas kernels, AOT-lowered to HLO text and
//!   executed from the `runtime` module via the PJRT C API (cargo feature
//!   `pjrt`; everything else builds dependency-free without it). Python
//!   is never on the request path.
//!
//! ## Quick map (paper concept → module)
//!
//! | Paper | Module |
//! |---|---|
//! | eq. 1 (edge/cloud decision) | [`coordinator::policy`] |
//! | eq. 2 (T_exe with N→M estimate) | [`predictor::texe`], [`predictor::n2m`] |
//! | T_tx timestamp tracking | [`predictor::ttx`] |
//! | offline characterisation | [`devices::calibration`] |
//! | RIPE-Atlas connection profiles | [`net::trace`] |
//! | IWSLT/OPUS corpora | [`corpus`] |
//! | 100k-request experiment | [`sim`], [`experiments::table1`] |
//! | queue-aware routing under load (beyond paper) | [`scheduler`], [`coordinator::router`] |
//! | hedged dispatch (beyond paper) | [`scheduler::dispatch`] |
//! | zero-churn dispatch core: slab arena + ring buffers (beyond paper) | [`scheduler::dispatch`], [`util::slab`], [`util::ring`] |
//! | frozen pre-rewrite dispatcher (differential + perf baseline) | [`scheduler::baseline`] |
//! | RLS online refit of T_exe and T_tx (beyond paper) | [`predictor::rls`] |
//! | throughput-vs-latency load sweep + drift scenario (beyond paper) | [`experiments::load`] |
//! | closed-loop latency–throughput curves (beyond paper) | [`experiments::load`], [`sim::harness`] |
//! | deterministic multi-threaded sweep runner (beyond paper) | [`experiments::runner`] |
//! | N-device fleet topologies + fleet-wide placement (beyond paper) | [`fleet`], [`scheduler::dispatch`] |
//! | fleet sweep across shapes (beyond paper) | [`experiments::fleet`], [`sim::harness`] |
//! | per-device refit banks at fleet scope (beyond paper) | [`predictor::bank`], [`fleet::select`] |
//! | closed-loop fleet drift sweep (beyond paper) | [`experiments::fleet`], [`sim::harness`] |
//! | self-tuning hedge waste budget (beyond paper) | [`scheduler::hedge`] |
//! | multi-tenant fair queueing (+ dispatcher front-end) (beyond paper) | [`scheduler::queue`], [`scheduler::dispatch`] |
//! | decision-log flight recorder + offline trace verification (beyond paper) | [`obs::recorder`], [`obs::verify`] |
//! | latency decomposition + control-loop telemetry (beyond paper) | [`obs::telemetry`], [`sim::harness`] |
//! | binary workload record/replay + streaming harness (beyond paper) | [`trace`], [`sim::harness`], [`util::json`] |

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod devices;
pub mod error;
pub mod experiments;
pub mod fleet;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod predictor;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod trace;
pub mod util;

pub use error::{Error, Result};
