//! Length-bucketed micro-batching.
//!
//! NMT inference cost is dominated by the serial O(M) decode loop
//! (`crate::runtime` runs one decode-step executable per output
//! token). Batching amortises that loop: a batch decodes for
//! max(M_i) steps regardless of how many sequences ride along, so the
//! cost of a batch is roughly its *longest* member plus a small
//! per-member residual (padding waste, wider matmuls) — which is only a
//! win when members have similar predicted output lengths. Hence
//! *length-bucketed* batching: requests are bucketed by the
//! [`crate::predictor::N2mRegressor`] estimate M̂ at admission, and a
//! batch is formed from same-bucket requests only (CoFormer and the
//! end-cloud pipeline line of work batch/pipe on the same insight; see
//! PAPERS.md).
//!
//! Formation is **opportunistic**: a batch is assembled only when a
//! worker is free, from requests that have already arrived — the
//! scheduler never delays a lone request to wait for companions, so at
//! low load batching adds zero latency and batches emerge naturally
//! exactly when queues are non-empty (i.e. when amortisation matters).
//!
//! The batcher is the only scheduler component that touches non-head
//! queue entries. It scans a bounded `lookahead` window for same-bucket
//! members, so batch formation is O(lookahead·max_batch) — constant per
//! batch, amortised O(1) per request — and head-of-line order is
//! preserved for everything it skips.
//!
//! Formation writes into a caller-owned scratch buffer
//! ([`form_batch_into`](BatchPolicy::form_batch_into)) that the
//! dispatcher reuses across batches, and cancelled hedge twins are
//! identified by a caller-supplied predicate over the queued record
//! itself (a generation-checked slab lookup in the dispatcher) — the
//! hot path allocates nothing and hashes nothing.

use super::queue::{AdmissionQueue, QueuedRequest};

/// Bucketing + batch-formation parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Width of one predicted-output-length bucket (tokens).
    pub bucket_width: f64,
    /// Maximum requests per micro-batch.
    pub max_batch: usize,
    /// How many queue positions past the head the batcher may inspect.
    pub lookahead: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { bucket_width: 8.0, max_batch: 8, lookahead: 32 }
    }
}

impl BatchPolicy {
    /// Disable batching (every batch is a single request).
    pub fn serial() -> Self {
        BatchPolicy { bucket_width: 8.0, max_batch: 1, lookahead: 0 }
    }

    /// Bucket index for a predicted output length.
    pub fn bucket_of(&self, m_est: f64) -> usize {
        assert!(self.bucket_width > 0.0);
        (m_est.max(0.0) / self.bucket_width) as usize
    }

    /// Pop the head request plus up to `max_batch - 1` same-bucket
    /// companions that arrived by `start_s`, scanning at most
    /// `lookahead` positions. Returns an empty vec on an empty queue.
    /// Allocating convenience wrapper over
    /// [`form_batch_into`](BatchPolicy::form_batch_into) for tests and
    /// one-off callers; the dispatcher uses the scratch-buffer form.
    pub fn form_batch(
        &self,
        queue: &mut AdmissionQueue,
        start_s: f64,
    ) -> Vec<QueuedRequest> {
        let mut batch = Vec::new();
        self.form_batch_into(queue, start_s, &mut batch, |_rq| false);
        batch
    }

    /// Form one batch into `batch` (cleared first; its capacity is
    /// reused across calls so steady-state formation is allocation-free).
    ///
    /// `purge` is the cancel-token predicate: it is consulted for the
    /// head and for every scanned entry, and when it returns `true` the
    /// entry is a cancelled hedge twin — it is removed from the queue
    /// (releasing its dead-slot marker), never executed, and consumes no
    /// lookahead budget (purges are deletions, not candidates). The
    /// callback owns any bookkeeping on its side (the dispatcher frees
    /// the twin's slab entry inside it). Used to drop the losing twin of
    /// a hedged request ([`crate::scheduler::Dispatcher::submit_hedged`]).
    pub fn form_batch_into<F>(
        &self,
        queue: &mut AdmissionQueue,
        start_s: f64,
        batch: &mut Vec<QueuedRequest>,
        mut purge: F,
    ) where
        F: FnMut(&QueuedRequest) -> bool,
    {
        batch.clear();
        // Purge cancelled heads first so the batch head is live. The
        // head is copied out (`QueuedRequest: Copy`) so the purge
        // callback can borrow the dispatcher's arena while we mutate
        // the queue.
        loop {
            let head = match queue.peek() {
                None => return,
                Some(h) => *h,
            };
            if purge(&head) {
                queue.pop();
                queue.unmark_dead();
            } else {
                break;
            }
        }
        let head = queue.pop().expect("peeked head exists");
        let bucket = head.bucket;
        batch.push(head);
        let mut i = 0usize;
        let mut scanned = 0usize;
        while batch.len() < self.max_batch && scanned < self.lookahead {
            let (candidate, rq_bucket, arrival_s) = match queue.get(i) {
                None => break,
                Some(rq) => (purge(rq), rq.bucket, rq.arrival_s),
            };
            if candidate {
                // Removal shifts the tail left; `i` now points at the
                // next candidate already.
                queue.remove(i);
                queue.unmark_dead();
                continue;
            }
            if rq_bucket == bucket && arrival_s <= start_s {
                let rq = queue.remove(i).expect("indexed element exists");
                batch.push(rq);
            } else {
                i += 1;
            }
            scanned += 1;
        }
    }
}

/// Running batch-size accounting (kept by the dispatcher).
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchStats {
    /// Batches dispatched.
    pub batches: u64,
    /// Requests across all batches.
    pub requests: u64,
}

impl BatchStats {
    /// Record one dispatched batch of `batch_len` requests.
    pub fn record(&mut self, batch_len: usize) {
        self.batches += 1;
        self.requests += batch_len as u64;
    }

    /// Mean requests per batch (NaN before any batch).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            f64::NAN
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn rq(id: u64, bucket: usize, arrival_s: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            payload: id as usize,
            n: 10,
            m_est: bucket as f64 * 8.0 + 1.0,
            est_service_s: 0.05,
            arrival_s,
            bucket,
            hedge: None,
        }
    }

    #[test]
    fn bucket_of_is_width_quantised() {
        let p = BatchPolicy::default();
        assert_eq!(p.bucket_of(0.0), 0);
        assert_eq!(p.bucket_of(7.9), 0);
        assert_eq!(p.bucket_of(8.0), 1);
        assert_eq!(p.bucket_of(63.9), 7);
        assert_eq!(p.bucket_of(-3.0), 0);
    }

    #[test]
    fn batches_same_bucket_only_and_preserves_skipped_order() {
        let p = BatchPolicy { bucket_width: 8.0, max_batch: 4, lookahead: 32 };
        let mut q = AdmissionQueue::new(16);
        for (id, bucket) in [(0, 1), (1, 2), (2, 1), (3, 1), (4, 2)] {
            q.offer(rq(id, bucket, 0.0));
        }
        let b = p.form_batch(&mut q, 1.0);
        let ids: Vec<u64> = b.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3]);
        // Skipped requests keep their order.
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 4);
        assert!(q.is_empty());
    }

    #[test]
    fn respects_max_batch_and_arrival_causality() {
        let p = BatchPolicy { bucket_width: 8.0, max_batch: 2, lookahead: 32 };
        let mut q = AdmissionQueue::new(16);
        q.offer(rq(0, 0, 0.0));
        q.offer(rq(1, 0, 5.0)); // arrives after the batch start
        q.offer(rq(2, 0, 0.5));
        let b = p.form_batch(&mut q, 1.0);
        let ids: Vec<u64> = b.iter().map(|r| r.id).collect();
        // id=1 must not be batched (arrival 5.0 > start 1.0); id=2 may.
        assert_eq!(ids, vec![0, 2]);
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn lookahead_bounds_the_scan() {
        let p = BatchPolicy { bucket_width: 8.0, max_batch: 8, lookahead: 2 };
        let mut q = AdmissionQueue::new(16);
        q.offer(rq(0, 0, 0.0));
        q.offer(rq(1, 1, 0.0));
        q.offer(rq(2, 1, 0.0));
        q.offer(rq(3, 0, 0.0)); // same bucket as head but out of window
        let b = p.form_batch(&mut q, 1.0);
        assert_eq!(b.len(), 1);
        assert_eq!(q.depth(), 3);
    }

    #[test]
    fn serial_policy_never_batches() {
        let p = BatchPolicy::serial();
        let mut q = AdmissionQueue::new(16);
        q.offer(rq(0, 0, 0.0));
        q.offer(rq(1, 0, 0.0));
        assert_eq!(p.form_batch(&mut q, 1.0).len(), 1);
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn empty_queue_gives_empty_batch() {
        let p = BatchPolicy::default();
        let mut q = AdmissionQueue::new(4);
        assert!(p.form_batch(&mut q, 0.0).is_empty());
    }

    #[test]
    fn scratch_buffer_is_cleared_and_reused() {
        let p = BatchPolicy { bucket_width: 8.0, max_batch: 4, lookahead: 32 };
        let mut q = AdmissionQueue::new(16);
        let mut batch = vec![rq(99, 0, 0.0)]; // stale content from a prior batch
        q.offer(rq(0, 0, 0.0));
        q.offer(rq(1, 0, 0.0));
        p.form_batch_into(&mut q, 1.0, &mut batch, |_rq| false);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1], "stale scratch content leaked into the batch");
        let cap = batch.capacity();
        p.form_batch_into(&mut q, 1.0, &mut batch, |_rq| false);
        assert!(batch.is_empty());
        assert_eq!(batch.capacity(), cap, "empty formation shrank the scratch");
    }

    #[test]
    fn purged_entries_skip_execution_and_lookahead_budget() {
        let p = BatchPolicy { bucket_width: 8.0, max_batch: 4, lookahead: 32 };
        let mut q = AdmissionQueue::new(16);
        for id in 0..5 {
            q.offer(rq(id, 0, 0.0));
        }
        // Cancel the head and one mid-queue entry; the predicate drains
        // its token set exactly once per purged entry.
        let mut cancelled: HashSet<u64> = [0u64, 2u64].into_iter().collect();
        q.mark_dead();
        q.mark_dead();
        let mut batch = Vec::new();
        p.form_batch_into(&mut q, 1.0, &mut batch, |rq| cancelled.remove(&rq.id));
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        // 0 and 2 purged, never executed; 1 heads the batch.
        assert_eq!(ids, vec![1, 3, 4]);
        assert!(cancelled.is_empty(), "purged ids must be consumed exactly once");
        assert!(q.is_empty());
        assert_eq!(q.live_depth(), 0, "dead markers released on purge");
    }

    #[test]
    fn cancelled_only_queue_yields_empty_batch() {
        let p = BatchPolicy::default();
        let mut q = AdmissionQueue::new(4);
        q.offer(rq(7, 0, 0.0));
        q.mark_dead();
        let mut cancelled: HashSet<u64> = [7u64].into_iter().collect();
        let mut batch = vec![rq(99, 0, 0.0)];
        p.form_batch_into(&mut q, 1.0, &mut batch, |rq| cancelled.remove(&rq.id));
        assert!(batch.is_empty());
        assert!(q.is_empty());
        assert!(cancelled.is_empty());
    }

    #[test]
    fn batch_stats_mean() {
        let mut s = BatchStats::default();
        assert!(s.mean_batch_size().is_nan());
        s.record(1);
        s.record(3);
        assert!((s.mean_batch_size() - 2.0).abs() < 1e-12);
    }
}
