//! Per-device in-flight tracking → expected queueing delay.
//!
//! The paper's eq. 1 compares `T_exe,e` against `T_tx + T_exe,c` as if
//! both devices were idle. Under load the dominant term is often neither
//! — it is the time a request spends *waiting* behind work that is
//! already executing or queued. This tracker converts what the scheduler
//! knows (worker busy-until times plus the [`crate::predictor::TexeModel`]
//! service estimates of every queued request) into an expected
//! queueing-delay estimate the router can add to each side of eq. 1:
//!
//! ```text
//! Ŵ_d(t) = ( Σ_workers max(busy_until - t, 0) + Σ_queued T̂_exe ) / workers
//! ```
//!
//! The backlog sum is maintained incrementally (add on admit, subtract
//! on dispatch), so the estimate is O(workers) — constant for a fixed
//! pool — not O(queue depth), and the earliest-free worker is cached
//! (recomputed once per dispatch, the only operation that changes it)
//! so the event loop's frequent next-start peeks are O(1). It
//! deliberately ignores batching amortisation, making it a mildly
//! conservative (over-)estimate of the true wait; see `scheduler::batch`
//! for why that bias is benign.

/// In-flight + backlog tracker for one device's worker pool.
#[derive(Debug, Clone)]
pub struct CapacityTracker {
    /// Per-worker busy-until time on the scheduler clock (seconds).
    free_at_s: Vec<f64>,
    /// Index of the worker that frees first (first index among ties);
    /// only [`on_dispatch`](CapacityTracker::on_dispatch) changes
    /// `free_at_s`, so the cache is refreshed there and nowhere else.
    earliest: usize,
    /// Sum of estimated service times of admitted-but-undispatched
    /// requests (seconds).
    backlog_est_s: f64,
    /// Batches dispatched (for utilisation reporting).
    dispatches: u64,
}

impl CapacityTracker {
    /// Tracker over `workers` worker slots (must be > 0).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "CapacityTracker needs workers > 0");
        CapacityTracker {
            free_at_s: vec![0.0; workers],
            earliest: 0,
            backlog_est_s: 0.0,
            dispatches: 0,
        }
    }

    /// Number of worker slots.
    pub fn workers(&self) -> usize {
        self.free_at_s.len()
    }

    /// A request with service estimate `est_service_s` entered the queue.
    #[inline]
    pub fn on_admit(&mut self, est_service_s: f64) {
        self.backlog_est_s += est_service_s.max(0.0);
    }

    /// A batch with summed member estimate `est_sum_s` left the queue for
    /// worker `worker`, which will be busy until `done_s`.
    pub fn on_dispatch(&mut self, worker: usize, est_sum_s: f64, done_s: f64) {
        self.backlog_est_s = (self.backlog_est_s - est_sum_s).max(0.0);
        self.free_at_s[worker] = done_s;
        self.dispatches += 1;
        // Refresh the earliest-free cache (O(workers), once per batch —
        // amortised across every O(1) peek the event loop makes).
        let mut best = (0usize, self.free_at_s[0]);
        for (i, &t) in self.free_at_s.iter().enumerate().skip(1) {
            if t < best.1 {
                best = (i, t);
            }
        }
        self.earliest = best.0;
    }

    /// A queued request with service estimate `est_service_s` was
    /// cancelled before dispatch (a hedge twin lost the race): reclaim
    /// its share of the backlog so the expected-wait estimate stops
    /// charging work that will never run.
    #[inline]
    pub fn on_cancel(&mut self, est_service_s: f64) {
        self.backlog_est_s = (self.backlog_est_s - est_service_s.max(0.0)).max(0.0);
    }

    /// Index and free-time of the worker that frees up first (cached:
    /// O(1)).
    #[inline]
    pub fn earliest_free(&self) -> (usize, f64) {
        (self.earliest, self.free_at_s[self.earliest])
    }

    /// Expected queueing delay for a request arriving at `now_s`:
    /// residual in-flight work plus the estimated backlog, spread over
    /// the pool.
    #[inline]
    pub fn expected_wait_s(&self, now_s: f64) -> f64 {
        let inflight: f64 = self
            .free_at_s
            .iter()
            .map(|&t| (t - now_s).max(0.0))
            .sum();
        (inflight + self.backlog_est_s) / self.free_at_s.len() as f64
    }

    /// Current backlog estimate (seconds of serial work).
    pub fn backlog_est_s(&self) -> f64 {
        self.backlog_est_s
    }

    /// Workers still executing a batch at `now_s` (the telemetry
    /// in-flight gauge).
    pub fn busy_workers(&self, now_s: f64) -> usize {
        self.free_at_s.iter().filter(|&&t| t > now_s).count()
    }

    /// Batches dispatched so far.
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Are all workers idle at `now_s` (ignoring the backlog)?
    pub fn all_idle(&self, now_s: f64) -> bool {
        self.free_at_s.iter().all(|&t| t <= now_s)
    }

    /// Hard reset after a device crash ([`crate::sim::FaultSpec`]): the
    /// device's memory is gone, so every in-flight batch and all queued
    /// backlog vanish — all workers read as free at `now_s`.
    pub fn reset_at(&mut self, now_s: f64) {
        for t in &mut self.free_at_s {
            *t = now_s;
        }
        self.backlog_est_s = 0.0;
        self.earliest = 0;
    }

    /// Clamp every worker's busy-until time to at least `now_s` — used
    /// when a crashed device recovers: it comes back idle *now*, never
    /// owing phantom work from before the outage. Refreshes the
    /// earliest-free cache (first index among ties, like
    /// [`CapacityTracker::on_dispatch`]).
    pub fn advance_to(&mut self, now_s: f64) {
        for t in &mut self.free_at_s {
            if *t < now_s {
                *t = now_s;
            }
        }
        let mut best = (0usize, self.free_at_s[0]);
        for (i, &t) in self.free_at_s.iter().enumerate().skip(1) {
            if t < best.1 {
                best = (i, t);
            }
        }
        self.earliest = best.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_pool_has_zero_wait() {
        let t = CapacityTracker::new(4);
        assert_eq!(t.expected_wait_s(0.0), 0.0);
        assert!(t.all_idle(0.0));
        assert_eq!(t.workers(), 4);
        assert_eq!(t.earliest_free(), (0, 0.0));
    }

    #[test]
    fn admit_then_dispatch_round_trips_backlog() {
        let mut t = CapacityTracker::new(1);
        t.on_admit(0.3);
        t.on_admit(0.2);
        assert!((t.backlog_est_s() - 0.5).abs() < 1e-12);
        assert!((t.expected_wait_s(0.0) - 0.5).abs() < 1e-12);
        t.on_dispatch(0, 0.3, 10.3);
        assert!((t.backlog_est_s() - 0.2).abs() < 1e-12);
        // At t=10 the worker still owes 0.3 s; backlog adds 0.2 s.
        assert!((t.expected_wait_s(10.0) - 0.5).abs() < 1e-12);
        // Residual decays with the clock.
        assert!((t.expected_wait_s(10.2) - 0.3).abs() < 1e-12);
        assert!((t.expected_wait_s(11.0) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn wait_spreads_over_workers() {
        let mut t1 = CapacityTracker::new(1);
        let mut t4 = CapacityTracker::new(4);
        for t in [&mut t1, &mut t4] {
            for _ in 0..8 {
                t.on_admit(0.1);
            }
        }
        assert!((t1.expected_wait_s(0.0) - 0.8).abs() < 1e-12);
        assert!((t4.expected_wait_s(0.0) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn earliest_free_picks_minimum() {
        let mut t = CapacityTracker::new(3);
        t.on_dispatch(0, 0.0, 5.0);
        t.on_dispatch(1, 0.0, 2.0);
        t.on_dispatch(2, 0.0, 9.0);
        assert_eq!(t.earliest_free(), (1, 2.0));
        assert_eq!(t.dispatches(), 3);
        assert!(!t.all_idle(4.0));
        assert!(t.all_idle(9.0));
    }

    #[test]
    fn earliest_free_cache_tracks_every_dispatch() {
        // The cached index must always agree with a shadow scan of the
        // same dispatch pattern (first index wins ties).
        let mut t = CapacityTracker::new(4);
        let mut shadow = vec![0.0f64; 4];
        let pattern = [
            (2usize, 7.0f64),
            (0, 3.0),
            (1, 3.0),
            (3, 1.0),
            (3, 8.0),
            (0, 2.0),
            (2, 2.0),
        ];
        for &(worker, done_s) in &pattern {
            t.on_dispatch(worker, 0.0, done_s);
            shadow[worker] = done_s;
            let mut best = (0usize, shadow[0]);
            for (i, &free_s) in shadow.iter().enumerate().skip(1) {
                if free_s < best.1 {
                    best = (i, free_s);
                }
            }
            assert_eq!(t.earliest_free(), best);
        }
        // Final state by construction: free times are
        // [2.0, 3.0, 2.0, 8.0] → worker 0 (first of the 2.0 tie).
        assert_eq!(t.earliest_free(), (0, 2.0));
    }

    #[test]
    fn backlog_never_goes_negative() {
        let mut t = CapacityTracker::new(1);
        t.on_admit(0.1);
        t.on_dispatch(0, 0.2, 1.0); // over-subtract (float drift guard)
        assert_eq!(t.backlog_est_s(), 0.0);
    }

    #[test]
    fn reset_at_wipes_inflight_and_backlog() {
        let mut t = CapacityTracker::new(2);
        t.on_admit(0.4);
        t.on_dispatch(1, 0.1, 9.0);
        t.reset_at(3.0);
        assert_eq!(t.backlog_est_s(), 0.0);
        assert_eq!(t.earliest_free(), (0, 3.0));
        assert!(t.all_idle(3.0));
        assert_eq!(t.expected_wait_s(3.0), 0.0);
    }

    #[test]
    fn advance_to_clamps_without_phantom_work() {
        let mut t = CapacityTracker::new(3);
        t.on_dispatch(0, 0.0, 5.0);
        t.on_dispatch(1, 0.0, 2.0);
        // Recovery at t=4: worker 1's stale 2.0 is clamped forward, the
        // still-future 5.0 is untouched, and the cache re-picks the
        // first minimum (worker 1 at 4.0 ties worker 2 — index 1 wins
        // only if it is first; here worker 2 also clamps to 4.0, so the
        // first min is worker 1).
        t.advance_to(4.0);
        assert_eq!(t.earliest_free(), (1, 4.0));
        assert!((t.expected_wait_s(4.0) - (1.0 / 3.0)).abs() < 1e-12);
        // Clamping past everything makes the pool idle with earliest 0.
        t.advance_to(9.0);
        assert_eq!(t.earliest_free(), (0, 9.0));
        assert!(t.all_idle(9.0));
    }

    #[test]
    fn cancel_reclaims_backlog_like_dispatch() {
        let mut t = CapacityTracker::new(2);
        t.on_admit(0.3);
        t.on_admit(0.2);
        t.on_cancel(0.3);
        assert!((t.backlog_est_s() - 0.2).abs() < 1e-12);
        assert!((t.expected_wait_s(0.0) - 0.1).abs() < 1e-12);
        // Over-cancel clamps at zero, like over-dispatch.
        t.on_cancel(5.0);
        assert_eq!(t.backlog_est_s(), 0.0);
    }
}
