//! Per-device in-flight tracking → expected queueing delay.
//!
//! The paper's eq. 1 compares `T_exe,e` against `T_tx + T_exe,c` as if
//! both devices were idle. Under load the dominant term is often neither
//! — it is the time a request spends *waiting* behind work that is
//! already executing or queued. This tracker converts what the scheduler
//! knows (worker busy-until times plus the [`crate::predictor::TexeModel`]
//! service estimates of every queued request) into an expected
//! queueing-delay estimate the router can add to each side of eq. 1:
//!
//! ```text
//! Ŵ_d(t) = ( Σ_workers max(busy_until - t, 0) + Σ_queued T̂_exe ) / workers
//! ```
//!
//! The backlog sum is maintained incrementally (add on admit, subtract
//! on dispatch), so the estimate is O(workers) — constant for a fixed
//! pool — not O(queue depth), and the earliest-free worker is cached
//! (recomputed once per dispatch, the only operation that changes it)
//! so the event loop's frequent next-start peeks are O(1). By default
//! it deliberately ignores batching amortisation, making it a mildly
//! conservative (over-)estimate of the true wait; see `scheduler::batch`
//! for why that bias is benign. When the estimate becomes load-bearing
//! (deadline-ordered scenario runs), the opt-in **batch-aware model**
//! ([`CapacityTracker::enable_batch_aware`]) fits a per-batch-size cost
//! ratio online — observed batch service time over the batch's summed
//! serial estimates — and discounts the backlog term by the warmed
//! ratio of the typical dispatched batch size, so backlog is no longer
//! priced as serial work (carried ROADMAP item). Off (the default) the
//! tracker carries no model state and every estimate is bit-identical
//! to the serial formula above.

/// Batch-size bins of the amortisation model (sizes 1..=8; larger
/// batches share the last bin — the dispatcher's default `max_batch`).
pub const BATCH_COST_BINS: usize = 8;
/// EWMA step of both the per-bin ratio fits and the typical-size fit.
pub const BATCH_COST_ALPHA: f64 = 0.1;
/// Dispatches the model must observe before it discounts anything.
pub const BATCH_COST_MIN_OBS: u64 = 16;
/// Floor of the backlog discount — amortisation never claims more than
/// an 8× speedup, so a wildly optimistic early fit cannot zero the
/// wait term and re-create the queue-blind pathology.
pub const BATCH_COST_MIN_DISCOUNT: f64 = 0.125;

/// Online per-batch-size amortisation fit (see the module docs). One
/// EWMA ratio per batch-size bin plus an EWMA of the dispatched batch
/// size; the backlog discount reads the typical size's warmed bin.
#[derive(Debug, Clone)]
struct BatchCost {
    /// `ratio[k]` ≈ E[service / Σ member estimates | batch size k+1].
    ratio: [f64; BATCH_COST_BINS],
    obs: [u64; BATCH_COST_BINS],
    /// EWMA of dispatched batch sizes — picks the bin the discount reads.
    mean_size: f64,
    total_obs: u64,
}

impl BatchCost {
    fn new() -> Self {
        BatchCost {
            ratio: [1.0; BATCH_COST_BINS],
            obs: [0; BATCH_COST_BINS],
            mean_size: 1.0,
            total_obs: 0,
        }
    }

    fn observe(&mut self, size: usize, est_sum_s: f64, service_s: f64) {
        if size == 0 || !(est_sum_s > 0.0) || !service_s.is_finite() || service_s < 0.0 {
            return;
        }
        // Bound the sample so one mispriced batch cannot wreck the fit.
        let r = (service_s / est_sum_s).clamp(0.0, 4.0);
        let b = size.min(BATCH_COST_BINS) - 1;
        if self.obs[b] == 0 {
            self.ratio[b] = r;
        } else {
            self.ratio[b] += BATCH_COST_ALPHA * (r - self.ratio[b]);
        }
        self.obs[b] += 1;
        if self.total_obs == 0 {
            self.mean_size = size as f64;
        } else {
            self.mean_size += BATCH_COST_ALPHA * (size as f64 - self.mean_size);
        }
        self.total_obs += 1;
    }

    /// Multiplier applied to the serial backlog sum: 1.0 until warmed,
    /// then the typical batch size's fitted ratio, floored so the wait
    /// term never vanishes entirely.
    fn discount(&self) -> f64 {
        if self.total_obs < BATCH_COST_MIN_OBS {
            return 1.0;
        }
        let b = (self.mean_size.round() as usize).clamp(1, BATCH_COST_BINS) - 1;
        if self.obs[b] == 0 {
            return 1.0;
        }
        self.ratio[b].clamp(BATCH_COST_MIN_DISCOUNT, 1.0)
    }
}

/// In-flight + backlog tracker for one device's worker pool.
#[derive(Debug, Clone)]
pub struct CapacityTracker {
    /// Per-worker busy-until time on the scheduler clock (seconds).
    free_at_s: Vec<f64>,
    /// Index of the worker that frees first (first index among ties);
    /// only [`on_dispatch`](CapacityTracker::on_dispatch) changes
    /// `free_at_s`, so the cache is refreshed there and nowhere else.
    earliest: usize,
    /// Sum of estimated service times of admitted-but-undispatched
    /// requests (seconds).
    backlog_est_s: f64,
    /// Batches dispatched (for utilisation reporting).
    dispatches: u64,
    /// Opt-in amortisation model ([`CapacityTracker::
    /// enable_batch_aware`]); `None` (the default) keeps the serial
    /// pricing and the pre-model struct behaviour exactly.
    cost: Option<BatchCost>,
}

impl CapacityTracker {
    /// Tracker over `workers` worker slots (must be > 0).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "CapacityTracker needs workers > 0");
        CapacityTracker {
            free_at_s: vec![0.0; workers],
            earliest: 0,
            backlog_est_s: 0.0,
            dispatches: 0,
            cost: None,
        }
    }

    /// Turn on the per-batch-size amortisation model (see module docs).
    /// Until [`BATCH_COST_MIN_OBS`] batches have been observed via
    /// [`CapacityTracker::observe_batch`] the wait estimate is unchanged.
    pub fn enable_batch_aware(&mut self) {
        if self.cost.is_none() {
            self.cost = Some(BatchCost::new());
        }
    }

    /// Is the amortisation model active?
    pub fn batch_aware(&self) -> bool {
        self.cost.is_some()
    }

    /// Feed the model one dispatched batch: its size, the sum of its
    /// members' serial service estimates, and the service time the
    /// executor actually charged. No-op unless
    /// [`CapacityTracker::enable_batch_aware`] was called.
    #[inline]
    pub fn observe_batch(&mut self, size: usize, est_sum_s: f64, service_s: f64) {
        if let Some(cost) = &mut self.cost {
            cost.observe(size, est_sum_s, service_s);
        }
    }

    /// Multiplier the wait estimate applies to the serial backlog sum
    /// (1.0 when the model is off or not yet warmed).
    pub fn backlog_discount(&self) -> f64 {
        match &self.cost {
            Some(cost) => cost.discount(),
            None => 1.0,
        }
    }

    /// Number of worker slots.
    pub fn workers(&self) -> usize {
        self.free_at_s.len()
    }

    /// A request with service estimate `est_service_s` entered the queue.
    #[inline]
    pub fn on_admit(&mut self, est_service_s: f64) {
        self.backlog_est_s += est_service_s.max(0.0);
    }

    /// A batch with summed member estimate `est_sum_s` left the queue for
    /// worker `worker`, which will be busy until `done_s`.
    pub fn on_dispatch(&mut self, worker: usize, est_sum_s: f64, done_s: f64) {
        self.backlog_est_s = (self.backlog_est_s - est_sum_s).max(0.0);
        self.free_at_s[worker] = done_s;
        self.dispatches += 1;
        // Refresh the earliest-free cache (O(workers), once per batch —
        // amortised across every O(1) peek the event loop makes).
        let mut best = (0usize, self.free_at_s[0]);
        for (i, &t) in self.free_at_s.iter().enumerate().skip(1) {
            if t < best.1 {
                best = (i, t);
            }
        }
        self.earliest = best.0;
    }

    /// A queued request with service estimate `est_service_s` was
    /// cancelled before dispatch (a hedge twin lost the race): reclaim
    /// its share of the backlog so the expected-wait estimate stops
    /// charging work that will never run.
    #[inline]
    pub fn on_cancel(&mut self, est_service_s: f64) {
        self.backlog_est_s = (self.backlog_est_s - est_service_s.max(0.0)).max(0.0);
    }

    /// Index and free-time of the worker that frees up first (cached:
    /// O(1)).
    #[inline]
    pub fn earliest_free(&self) -> (usize, f64) {
        (self.earliest, self.free_at_s[self.earliest])
    }

    /// Expected queueing delay for a request arriving at `now_s`:
    /// residual in-flight work plus the estimated backlog, spread over
    /// the pool.
    #[inline]
    pub fn expected_wait_s(&self, now_s: f64) -> f64 {
        let inflight: f64 = self
            .free_at_s
            .iter()
            .map(|&t| (t - now_s).max(0.0))
            .sum();
        // The disabled path keeps the exact pre-model expression (no
        // ×1.0 detour) so legacy runs stay bit-identical by structure,
        // not by accident of float identities.
        match &self.cost {
            Some(cost) => {
                (inflight + self.backlog_est_s * cost.discount()) / self.free_at_s.len() as f64
            }
            None => (inflight + self.backlog_est_s) / self.free_at_s.len() as f64,
        }
    }

    /// Current backlog estimate (seconds of serial work).
    pub fn backlog_est_s(&self) -> f64 {
        self.backlog_est_s
    }

    /// Workers still executing a batch at `now_s` (the telemetry
    /// in-flight gauge).
    pub fn busy_workers(&self, now_s: f64) -> usize {
        self.free_at_s.iter().filter(|&&t| t > now_s).count()
    }

    /// Batches dispatched so far.
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Are all workers idle at `now_s` (ignoring the backlog)?
    pub fn all_idle(&self, now_s: f64) -> bool {
        self.free_at_s.iter().all(|&t| t <= now_s)
    }

    /// Hard reset after a device crash ([`crate::sim::FaultSpec`]): the
    /// device's memory is gone, so every in-flight batch and all queued
    /// backlog vanish — all workers read as free at `now_s`.
    pub fn reset_at(&mut self, now_s: f64) {
        for t in &mut self.free_at_s {
            *t = now_s;
        }
        self.backlog_est_s = 0.0;
        self.earliest = 0;
    }

    /// Clamp every worker's busy-until time to at least `now_s` — used
    /// when a crashed device recovers: it comes back idle *now*, never
    /// owing phantom work from before the outage. Refreshes the
    /// earliest-free cache (first index among ties, like
    /// [`CapacityTracker::on_dispatch`]).
    pub fn advance_to(&mut self, now_s: f64) {
        for t in &mut self.free_at_s {
            if *t < now_s {
                *t = now_s;
            }
        }
        let mut best = (0usize, self.free_at_s[0]);
        for (i, &t) in self.free_at_s.iter().enumerate().skip(1) {
            if t < best.1 {
                best = (i, t);
            }
        }
        self.earliest = best.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_pool_has_zero_wait() {
        let t = CapacityTracker::new(4);
        assert_eq!(t.expected_wait_s(0.0), 0.0);
        assert!(t.all_idle(0.0));
        assert_eq!(t.workers(), 4);
        assert_eq!(t.earliest_free(), (0, 0.0));
    }

    #[test]
    fn admit_then_dispatch_round_trips_backlog() {
        let mut t = CapacityTracker::new(1);
        t.on_admit(0.3);
        t.on_admit(0.2);
        assert!((t.backlog_est_s() - 0.5).abs() < 1e-12);
        assert!((t.expected_wait_s(0.0) - 0.5).abs() < 1e-12);
        t.on_dispatch(0, 0.3, 10.3);
        assert!((t.backlog_est_s() - 0.2).abs() < 1e-12);
        // At t=10 the worker still owes 0.3 s; backlog adds 0.2 s.
        assert!((t.expected_wait_s(10.0) - 0.5).abs() < 1e-12);
        // Residual decays with the clock.
        assert!((t.expected_wait_s(10.2) - 0.3).abs() < 1e-12);
        assert!((t.expected_wait_s(11.0) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn wait_spreads_over_workers() {
        let mut t1 = CapacityTracker::new(1);
        let mut t4 = CapacityTracker::new(4);
        for t in [&mut t1, &mut t4] {
            for _ in 0..8 {
                t.on_admit(0.1);
            }
        }
        assert!((t1.expected_wait_s(0.0) - 0.8).abs() < 1e-12);
        assert!((t4.expected_wait_s(0.0) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn earliest_free_picks_minimum() {
        let mut t = CapacityTracker::new(3);
        t.on_dispatch(0, 0.0, 5.0);
        t.on_dispatch(1, 0.0, 2.0);
        t.on_dispatch(2, 0.0, 9.0);
        assert_eq!(t.earliest_free(), (1, 2.0));
        assert_eq!(t.dispatches(), 3);
        assert!(!t.all_idle(4.0));
        assert!(t.all_idle(9.0));
    }

    #[test]
    fn earliest_free_cache_tracks_every_dispatch() {
        // The cached index must always agree with a shadow scan of the
        // same dispatch pattern (first index wins ties).
        let mut t = CapacityTracker::new(4);
        let mut shadow = vec![0.0f64; 4];
        let pattern = [
            (2usize, 7.0f64),
            (0, 3.0),
            (1, 3.0),
            (3, 1.0),
            (3, 8.0),
            (0, 2.0),
            (2, 2.0),
        ];
        for &(worker, done_s) in &pattern {
            t.on_dispatch(worker, 0.0, done_s);
            shadow[worker] = done_s;
            let mut best = (0usize, shadow[0]);
            for (i, &free_s) in shadow.iter().enumerate().skip(1) {
                if free_s < best.1 {
                    best = (i, free_s);
                }
            }
            assert_eq!(t.earliest_free(), best);
        }
        // Final state by construction: free times are
        // [2.0, 3.0, 2.0, 8.0] → worker 0 (first of the 2.0 tie).
        assert_eq!(t.earliest_free(), (0, 2.0));
    }

    #[test]
    fn backlog_never_goes_negative() {
        let mut t = CapacityTracker::new(1);
        t.on_admit(0.1);
        t.on_dispatch(0, 0.2, 1.0); // over-subtract (float drift guard)
        assert_eq!(t.backlog_est_s(), 0.0);
    }

    #[test]
    fn reset_at_wipes_inflight_and_backlog() {
        let mut t = CapacityTracker::new(2);
        t.on_admit(0.4);
        t.on_dispatch(1, 0.1, 9.0);
        t.reset_at(3.0);
        assert_eq!(t.backlog_est_s(), 0.0);
        assert_eq!(t.earliest_free(), (0, 3.0));
        assert!(t.all_idle(3.0));
        assert_eq!(t.expected_wait_s(3.0), 0.0);
    }

    #[test]
    fn advance_to_clamps_without_phantom_work() {
        let mut t = CapacityTracker::new(3);
        t.on_dispatch(0, 0.0, 5.0);
        t.on_dispatch(1, 0.0, 2.0);
        // Recovery at t=4: worker 1's stale 2.0 is clamped forward, the
        // still-future 5.0 is untouched, and the cache re-picks the
        // first minimum (worker 1 at 4.0 ties worker 2 — index 1 wins
        // only if it is first; here worker 2 also clamps to 4.0, so the
        // first min is worker 1).
        t.advance_to(4.0);
        assert_eq!(t.earliest_free(), (1, 4.0));
        assert!((t.expected_wait_s(4.0) - (1.0 / 3.0)).abs() < 1e-12);
        // Clamping past everything makes the pool idle with earliest 0.
        t.advance_to(9.0);
        assert_eq!(t.earliest_free(), (0, 9.0));
        assert!(t.all_idle(9.0));
    }

    #[test]
    fn batch_aware_off_is_bit_identical() {
        // The model must be strictly pay-for-use: a tracker that never
        // enables it prices backlog serially, and observe_batch is a
        // no-op rather than silently arming anything.
        let mut plain = CapacityTracker::new(2);
        let mut poked = CapacityTracker::new(2);
        for t in [&mut plain, &mut poked] {
            for _ in 0..6 {
                t.on_admit(0.25);
            }
            t.on_dispatch(0, 0.5, 1.5);
        }
        for _ in 0..200 {
            poked.observe_batch(8, 1.0, 0.2);
        }
        assert!(!poked.batch_aware());
        assert_eq!(poked.backlog_discount(), 1.0);
        assert_eq!(
            plain.expected_wait_s(0.7).to_bits(),
            poked.expected_wait_s(0.7).to_bits()
        );
    }

    #[test]
    fn batch_aware_warms_up_before_discounting() {
        let mut t = CapacityTracker::new(1);
        t.enable_batch_aware();
        assert!(t.batch_aware());
        t.on_admit(1.0);
        // Below the warmup threshold nothing changes even though every
        // sample says batching halves the work.
        for _ in 0..(BATCH_COST_MIN_OBS - 1) {
            t.observe_batch(4, 1.0, 0.5);
        }
        assert_eq!(t.backlog_discount(), 1.0);
        assert!((t.expected_wait_s(0.0) - 1.0).abs() < 1e-12);
        // One more observation crosses the threshold; the EWMA saw only
        // 0.5 ratios, so the discount is exactly 0.5 and the backlog
        // term is repriced.
        t.observe_batch(4, 1.0, 0.5);
        assert!((t.backlog_discount() - 0.5).abs() < 1e-12);
        assert!((t.expected_wait_s(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn batch_aware_discount_is_clamped_both_ways() {
        // Ratios above 1 (estimates too optimistic) must never inflate
        // the wait beyond the serial price...
        let mut hi = CapacityTracker::new(1);
        hi.enable_batch_aware();
        for _ in 0..BATCH_COST_MIN_OBS {
            hi.observe_batch(2, 1.0, 3.0);
        }
        assert_eq!(hi.backlog_discount(), 1.0);
        // ...and absurdly small ratios are floored so the wait term
        // cannot vanish.
        let mut lo = CapacityTracker::new(1);
        lo.enable_batch_aware();
        for _ in 0..BATCH_COST_MIN_OBS {
            lo.observe_batch(8, 1.0, 0.001);
        }
        assert_eq!(lo.backlog_discount(), BATCH_COST_MIN_DISCOUNT);
    }

    #[test]
    fn batch_aware_reads_typical_size_bin() {
        let mut t = CapacityTracker::new(1);
        t.enable_batch_aware();
        // Size-1 batches have ratio 1.0; size-4 batches run at 0.4.
        // After a long run of size-4 dispatches the typical size is 4,
        // so the discount reads the size-4 bin, not the stale size-1 one.
        t.observe_batch(1, 1.0, 1.0);
        for _ in 0..64 {
            t.observe_batch(4, 1.0, 0.4);
        }
        assert!((t.backlog_discount() - 0.4).abs() < 1e-9);
        // Degenerate samples are ignored outright.
        t.observe_batch(0, 1.0, 0.4);
        t.observe_batch(4, 0.0, 0.4);
        t.observe_batch(4, 1.0, f64::NAN);
        assert!((t.backlog_discount() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn cancel_reclaims_backlog_like_dispatch() {
        let mut t = CapacityTracker::new(2);
        t.on_admit(0.3);
        t.on_admit(0.2);
        t.on_cancel(0.3);
        assert!((t.backlog_est_s() - 0.2).abs() < 1e-12);
        assert!((t.expected_wait_s(0.0) - 0.1).abs() < 1e-12);
        // Over-cancel clamps at zero, like over-dispatch.
        t.on_cancel(5.0);
        assert_eq!(t.backlog_est_s(), 0.0);
    }
}
