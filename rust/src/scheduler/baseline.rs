//! Frozen **pre-rewrite** dispatcher: the hash-churn implementation the
//! zero-churn core replaced, kept as a living baseline.
//!
//! This is a faithful port of the dispatcher as it stood before the
//! slab-arena rewrite: `VecDeque` admission queues, in-flight hedge
//! races in an id-keyed `HashMap`, cancel tokens in a side `HashSet`,
//! a fresh `Vec` allocated for every formed batch, and an O(workers)
//! earliest-free scan on every event peek. It exists for two reasons:
//!
//! 1. **Differential oracle** — the rewrite must be a pure data-
//!    structure change: `tests/proptest_invariants.rs` replays random
//!    solo/hedged streams through both implementations and asserts the
//!    completion sequences are identical (same ids, devices, kinds and
//!    bit-equal times). Any future scheduler change that breaks
//!    equivalence is either a deliberate semantic change (update this
//!    file in lockstep) or a bug (fix it).
//! 2. **Perf baseline** — `cnmt bench sched` drives the same stream
//!    through both in one binary and reports
//!    `speedup_vs_baseline`; CI gates on it, so the "pre-change
//!    baseline measured in the same container" in `BENCH_sched.json`
//!    is reproducible anywhere a toolchain exists.
//!
//! Do not "optimise" this module — its slowness is its purpose.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};

use crate::devices::DeviceKind;

use super::batch::{BatchPolicy, BatchStats};
use super::dispatch::{BatchExecutor, Completion, CompletionKind, HedgeOutcome, HedgeStats};
use super::queue::{Admission, QueuedRequest};

/// Pre-rewrite bounded FIFO queue (`VecDeque` storage, live-depth
/// admission bound with lazy-purge dead counting; the stats counters
/// of the original are dropped — nothing here reads them).
#[derive(Debug, Clone)]
struct ChurnQueue {
    items: VecDeque<QueuedRequest>,
    max_depth: usize,
    dead: usize,
}

impl ChurnQueue {
    fn new(max_depth: usize) -> Self {
        assert!(max_depth > 0, "ChurnQueue needs max_depth > 0");
        ChurnQueue {
            items: VecDeque::with_capacity(max_depth.min(1024)),
            max_depth,
            dead: 0,
        }
    }

    fn live_depth(&self) -> usize {
        self.items.len().saturating_sub(self.dead)
    }

    fn offer(&mut self, rq: QueuedRequest) -> Admission {
        if self.live_depth() >= self.max_depth {
            return Admission::Rejected;
        }
        self.items.push_back(rq);
        Admission::Admitted { depth: self.live_depth() }
    }
}

/// Pre-rewrite per-worker tracker (uncached earliest-free scan).
#[derive(Debug, Clone)]
struct ChurnTracker {
    free_at_s: Vec<f64>,
    backlog_est_s: f64,
}

impl ChurnTracker {
    fn new(workers: usize) -> Self {
        assert!(workers > 0);
        ChurnTracker { free_at_s: vec![0.0; workers], backlog_est_s: 0.0 }
    }

    fn on_admit(&mut self, est_service_s: f64) {
        self.backlog_est_s += est_service_s.max(0.0);
    }

    fn on_dispatch(&mut self, worker: usize, est_sum_s: f64, done_s: f64) {
        self.backlog_est_s = (self.backlog_est_s - est_sum_s).max(0.0);
        self.free_at_s[worker] = done_s;
    }

    fn on_cancel(&mut self, est_service_s: f64) {
        self.backlog_est_s = (self.backlog_est_s - est_service_s.max(0.0)).max(0.0);
    }

    fn earliest_free(&self) -> (usize, f64) {
        let mut best = (0usize, self.free_at_s[0]);
        for (i, &t) in self.free_at_s.iter().enumerate().skip(1) {
            if t < best.1 {
                best = (i, t);
            }
        }
        best
    }

    fn expected_wait_s(&self, now_s: f64) -> f64 {
        let inflight: f64 = self.free_at_s.iter().map(|&t| (t - now_s).max(0.0)).sum();
        (inflight + self.backlog_est_s) / self.free_at_s.len() as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CopyState {
    Queued,
    Running,
    Done,
}

#[derive(Debug, Clone, Copy)]
struct HedgeEntry {
    est: [f64; 2],
    state: [CopyState; 2],
    winner: Option<DeviceKind>,
}

#[derive(Debug, Clone, Copy)]
struct Pending {
    done_s: f64,
    seq: u64,
    start_s: f64,
    batch_size: usize,
    device: DeviceKind,
    request: QueuedRequest,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.done_s == other.done_s && self.seq == other.seq
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.done_s
            .total_cmp(&other.done_s)
            .then(self.seq.cmp(&other.seq))
    }
}

#[derive(Debug, Clone)]
struct Lane {
    queue: ChurnQueue,
    tracker: ChurnTracker,
}

impl Lane {
    fn offer(&mut self, rq: QueuedRequest) -> Admission {
        let admission = self.queue.offer(rq);
        if admission.is_admitted() {
            self.tracker.on_admit(rq.est_service_s);
        }
        admission
    }
}

fn lane_idx(device: DeviceKind) -> usize {
    match device {
        DeviceKind::Edge => 0,
        DeviceKind::Cloud => 1,
    }
}

fn other(device: DeviceKind) -> DeviceKind {
    match device {
        DeviceKind::Edge => DeviceKind::Cloud,
        DeviceKind::Cloud => DeviceKind::Edge,
    }
}

/// The pre-rewrite two-lane dispatcher (see the module docs). Public
/// API mirrors [`super::Dispatcher`] so benches and differential tests
/// can drive either.
#[derive(Debug, Clone)]
pub struct BaselineDispatcher {
    edge: Lane,
    cloud: Lane,
    policy: BatchPolicy,
    stats: BatchStats,
    pending: BinaryHeap<Reverse<Pending>>,
    seq: u64,
    hedges: HashMap<u64, HedgeEntry>,
    cancelled: HashSet<u64>,
    hedge_stats: HedgeStats,
}

impl BaselineDispatcher {
    /// Build from the same sizing parameters as the real dispatcher.
    pub fn new(cfg: &super::DispatcherConfig) -> Self {
        BaselineDispatcher {
            edge: Lane {
                queue: ChurnQueue::new(cfg.max_queue_depth),
                tracker: ChurnTracker::new(cfg.edge_workers),
            },
            cloud: Lane {
                queue: ChurnQueue::new(cfg.max_queue_depth),
                tracker: ChurnTracker::new(cfg.cloud_workers),
            },
            policy: cfg.batch,
            stats: BatchStats::default(),
            pending: BinaryHeap::new(),
            seq: 0,
            hedges: HashMap::new(),
            cancelled: HashSet::new(),
            hedge_stats: HedgeStats::default(),
        }
    }

    fn lane_mut(&mut self, device: DeviceKind) -> &mut Lane {
        match device {
            DeviceKind::Edge => &mut self.edge,
            DeviceKind::Cloud => &mut self.cloud,
        }
    }

    /// Expected queueing delay on `device` at `now_s`.
    pub fn expected_wait_s(&self, device: DeviceKind, now_s: f64) -> f64 {
        match device {
            DeviceKind::Edge => self.edge.tracker.expected_wait_s(now_s),
            DeviceKind::Cloud => self.cloud.tracker.expected_wait_s(now_s),
        }
    }

    /// Solo submission (bucket assigned here, as in the old code).
    pub fn submit(&mut self, device: DeviceKind, mut rq: QueuedRequest) -> Admission {
        rq.bucket = self.policy.bucket_of(rq.m_est);
        rq.hedge = None;
        self.lane_mut(device).offer(rq)
    }

    /// Hedged submission, id-keyed (the pre-rewrite bookkeeping).
    pub fn submit_hedged(
        &mut self,
        mut rq: QueuedRequest,
        edge_est_s: f64,
        cloud_est_s: f64,
    ) -> HedgeOutcome {
        rq.bucket = self.policy.bucket_of(rq.m_est);
        rq.hedge = None;
        let mut edge_rq = rq;
        edge_rq.est_service_s = edge_est_s;
        let mut cloud_rq = rq;
        cloud_rq.est_service_s = cloud_est_s;
        let edge_ok = self.edge.offer(edge_rq).is_admitted();
        let cloud_ok = self.cloud.offer(cloud_rq).is_admitted();
        match (edge_ok, cloud_ok) {
            (true, true) => {
                self.hedge_stats.hedged += 1;
                self.hedges.insert(
                    rq.id,
                    HedgeEntry {
                        est: [edge_est_s, cloud_est_s],
                        state: [CopyState::Queued, CopyState::Queued],
                        winner: None,
                    },
                );
                HedgeOutcome::Hedged
            }
            (true, false) => HedgeOutcome::Single(DeviceKind::Edge),
            (false, true) => HedgeOutcome::Single(DeviceKind::Cloud),
            (false, false) => HedgeOutcome::Rejected,
        }
    }

    /// Batch-size accounting.
    pub fn batch_stats(&self) -> BatchStats {
        self.stats
    }

    /// Hedge outcome counters.
    pub fn hedge_stats(&self) -> HedgeStats {
        self.hedge_stats
    }

    /// No queued work and no in-flight batches?
    pub fn idle(&self) -> bool {
        self.edge.queue.items.is_empty()
            && self.cloud.queue.items.is_empty()
            && self.pending.is_empty()
    }

    fn lane_next_start(&mut self, device: DeviceKind) -> Option<f64> {
        loop {
            let lane = match device {
                DeviceKind::Edge => &self.edge,
                DeviceKind::Cloud => &self.cloud,
            };
            let (head_id, head_arrival) = match lane.queue.items.front() {
                None => return None,
                Some(h) => (h.id, h.arrival_s),
            };
            if self.cancelled.contains(&head_id) {
                let lane = self.lane_mut(device);
                lane.queue.items.pop_front();
                lane.queue.dead = lane.queue.dead.saturating_sub(1);
                self.cancelled.remove(&head_id);
                continue;
            }
            let (_worker, free_s) = lane.tracker.earliest_free();
            return Some(free_s.max(head_arrival));
        }
    }

    fn next_batch_start(&mut self) -> Option<(DeviceKind, f64)> {
        let e = self.lane_next_start(DeviceKind::Edge);
        let c = self.lane_next_start(DeviceKind::Cloud);
        match (e, c) {
            (None, None) => None,
            (Some(s), None) => Some((DeviceKind::Edge, s)),
            (None, Some(s)) => Some((DeviceKind::Cloud, s)),
            (Some(se), Some(sc)) => {
                if se <= sc {
                    Some((DeviceKind::Edge, se))
                } else {
                    Some((DeviceKind::Cloud, sc))
                }
            }
        }
    }

    /// Old-style batch formation: fresh `Vec` per batch, cancel tokens
    /// consulted through the side set.
    fn form_batch(&mut self, device: DeviceKind, start_s: f64) -> Vec<QueuedRequest> {
        let (queue, cancelled, policy) = match device {
            DeviceKind::Edge => (&mut self.edge.queue, &mut self.cancelled, &self.policy),
            DeviceKind::Cloud => (&mut self.cloud.queue, &mut self.cancelled, &self.policy),
        };
        loop {
            let head_id = match queue.items.front() {
                None => return Vec::new(),
                Some(h) => h.id,
            };
            if cancelled.contains(&head_id) {
                queue.items.pop_front();
                queue.dead = queue.dead.saturating_sub(1);
                cancelled.remove(&head_id);
            } else {
                break;
            }
        }
        let head = queue.items.pop_front().expect("peeked head exists");
        let bucket = head.bucket;
        let mut batch = Vec::with_capacity(policy.max_batch.min(8));
        batch.push(head);
        let mut i = 0usize;
        let mut scanned = 0usize;
        while batch.len() < policy.max_batch && scanned < policy.lookahead {
            let (id, rq_bucket, arrival_s) = match queue.items.get(i) {
                None => break,
                Some(rq) => (rq.id, rq.bucket, rq.arrival_s),
            };
            if cancelled.contains(&id) {
                queue.items.remove(i);
                queue.dead = queue.dead.saturating_sub(1);
                cancelled.remove(&id);
                continue;
            }
            if rq_bucket == bucket && arrival_s <= start_s {
                let rq = queue.items.remove(i).expect("indexed element exists");
                batch.push(rq);
            } else {
                i += 1;
            }
            scanned += 1;
        }
        batch
    }

    fn dispatch_at<E>(&mut self, device: DeviceKind, start_s: f64, exec: &mut E)
    where
        E: BatchExecutor,
    {
        let batch = self.form_batch(device, start_s);
        if batch.is_empty() {
            return;
        }
        let di = lane_idx(device);
        for rq in &batch {
            if let Some(entry) = self.hedges.get_mut(&rq.id) {
                entry.state[di] = CopyState::Running;
            }
        }
        let est_sum: f64 = batch.iter().map(|r| r.est_service_s).sum();
        let service_s = exec.execute(device, &batch, start_s).max(0.0);
        let done_s = start_s + service_s;
        {
            let lane = self.lane_mut(device);
            let (worker, _free) = lane.tracker.earliest_free();
            lane.tracker.on_dispatch(worker, est_sum, done_s);
        }
        self.stats.record(batch.len());
        let batch_size = batch.len();
        for request in batch {
            let seq = self.seq;
            self.seq += 1;
            self.pending.push(Reverse(Pending {
                done_s,
                seq,
                start_s,
                batch_size,
                device,
                request,
            }));
        }
    }

    fn resolve_completion(&mut self, device: DeviceKind, id: u64) -> CompletionKind {
        let (kind, cancel_twin) = {
            let entry = match self.hedges.get_mut(&id) {
                None => return CompletionKind::Solo,
                Some(e) => e,
            };
            let di = lane_idx(device);
            entry.state[di] = CopyState::Done;
            if entry.winner.is_some() {
                (CompletionKind::HedgeLoss, None)
            } else {
                entry.winner = Some(device);
                let ti = lane_idx(other(device));
                match entry.state[ti] {
                    CopyState::Queued => {
                        (CompletionKind::HedgeWin, Some((other(device), entry.est[ti])))
                    }
                    _ => (CompletionKind::HedgeWin, None),
                }
            }
        };
        match kind {
            CompletionKind::HedgeLoss => {
                self.hedges.remove(&id);
                self.hedge_stats.losers_run += 1;
            }
            CompletionKind::HedgeWin => {
                match device {
                    DeviceKind::Edge => self.hedge_stats.wins_edge += 1,
                    DeviceKind::Cloud => self.hedge_stats.wins_cloud += 1,
                }
                if let Some((twin, est)) = cancel_twin {
                    self.cancelled.insert(id);
                    self.hedge_stats.cancelled_unrun += 1;
                    let lane = self.lane_mut(twin);
                    lane.tracker.on_cancel(est);
                    lane.queue.dead += 1;
                    self.hedges.remove(&id);
                }
            }
            CompletionKind::Solo => {}
        }
        kind
    }

    fn flush_one<F>(&mut self, on_complete: &mut F)
    where
        F: FnMut(Completion),
    {
        let Reverse(p) = self.pending.pop().expect("pending completion exists");
        let kind = self.resolve_completion(p.device, p.request.id);
        on_complete(Completion {
            request: p.request,
            device: p.device,
            lane: lane_idx(p.device),
            start_s: p.start_s,
            done_s: p.done_s,
            batch_size: p.batch_size,
            kind,
        });
    }

    /// Process the earliest event at or before `horizon_s` (completions
    /// first on ties).
    pub fn step<E, F>(&mut self, horizon_s: f64, exec: &mut E, on_complete: &mut F) -> bool
    where
        E: BatchExecutor,
        F: FnMut(Completion),
    {
        let next_start = self.next_batch_start();
        let next_done = self.pending.peek().map(|p| p.0.done_s);
        let completion_first = match (next_start, next_done) {
            (None, None) => return false,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (Some((_d, s)), Some(t)) => t <= s,
        };
        if completion_first {
            let done_s = next_done.expect("peeked completion exists");
            if done_s > horizon_s {
                return false;
            }
            self.flush_one(on_complete);
        } else {
            let (device, start_s) = next_start.expect("peeked start exists");
            if start_s > horizon_s {
                return false;
            }
            self.dispatch_at(device, start_s, exec);
        }
        true
    }

    /// Process every event up to and including `horizon_s`.
    pub fn run_until<E, F>(&mut self, horizon_s: f64, exec: &mut E, on_complete: &mut F)
    where
        E: BatchExecutor,
        F: FnMut(Completion),
    {
        while self.step(horizon_s, exec, on_complete) {}
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Dispatcher, DispatcherConfig};
    use super::*;

    struct AsymExec {
        edge_s: f64,
        cloud_s: f64,
    }

    impl BatchExecutor for AsymExec {
        fn execute(&mut self, d: DeviceKind, _b: &[QueuedRequest], _s: f64) -> f64 {
            match d {
                DeviceKind::Edge => self.edge_s,
                DeviceKind::Cloud => self.cloud_s,
            }
        }
    }

    fn rq(id: u64, arrival_s: f64, m_est: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            payload: id as usize,
            n: 10,
            m_est,
            est_service_s: 0.1,
            arrival_s,
            bucket: 0,
            hedge: None,
        }
    }

    #[test]
    fn baseline_matches_dense_on_a_mixed_stream() {
        // A compact deterministic differential check (the heavy random
        // version lives in tests/proptest_invariants.rs): same stream,
        // same completions, bit-equal times.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            max_queue_depth: 8,
            ..Default::default()
        };
        let mut a = BaselineDispatcher::new(&cfg);
        let mut b = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.03, cloud_s: 0.011 };
        let mut ca = Vec::new();
        let mut cb = Vec::new();
        for i in 0..200u64 {
            let t = i as f64 * 0.004;
            a.run_until(t, &mut exec, &mut |c| ca.push(c));
            b.run_until(t, &mut exec, &mut |c| cb.push(c));
            let r = rq(i, t, (i % 48) as f64);
            if i % 4 == 0 {
                assert_eq!(
                    a.submit_hedged(r, 0.03, 0.011),
                    b.submit_hedged(r, 0.03, 0.011)
                );
            } else {
                let d = if i % 2 == 0 { DeviceKind::Edge } else { DeviceKind::Cloud };
                assert_eq!(
                    a.submit(d, r).is_admitted(),
                    b.submit(d, r).is_admitted()
                );
            }
        }
        a.run_until(f64::INFINITY, &mut exec, &mut |c| ca.push(c));
        b.run_until(f64::INFINITY, &mut exec, &mut |c| cb.push(c));
        assert_eq!(ca.len(), cb.len());
        for (x, y) in ca.iter().zip(&cb) {
            assert_eq!(x.request.id, y.request.id);
            assert_eq!(x.device, y.device);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.done_s.to_bits(), y.done_s.to_bits());
            assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
            assert_eq!(x.batch_size, y.batch_size);
        }
        let (ha, hb) = (a.hedge_stats(), b.hedge_stats());
        assert_eq!(ha.hedged, hb.hedged);
        assert_eq!(ha.wins_edge, hb.wins_edge);
        assert_eq!(ha.wins_cloud, hb.wins_cloud);
        assert_eq!(ha.cancelled_unrun, hb.cancelled_unrun);
        assert_eq!(ha.losers_run, hb.losers_run);
        assert!(a.idle() && b.idle());
    }
}
