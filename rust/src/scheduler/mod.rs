//! Load-aware scheduling: admission queues, capacity tracking,
//! length-bucketed micro-batching and worker-pool dispatch.
//!
//! The paper routes each request in isolation, assuming an idle edge and
//! an idle cloud (eq. 1). This subsystem supplies everything the router
//! needs to stay optimal when that assumption breaks under heavy
//! traffic:
//!
//! * [`queue`] — per-device bounded admission queues with arrival
//!   timestamps and shed/reject accounting;
//! * [`capacity`] — per-device in-flight tracking that converts queue
//!   contents into an expected queueing-delay estimate using the
//!   [`crate::predictor::TexeModel`] planes;
//! * [`batch`] — length-bucketed micro-batching keyed on the
//!   [`crate::predictor::N2mRegressor`] estimate M̂, amortising the
//!   serial O(M) decode loop across compatible requests;
//! * [`dispatch`] — the N-lane worker-pool dispatcher tying the above
//!   together behind backend-agnostic executors ([`BatchExecutor`] for
//!   the classic pair, [`LaneExecutor`] for heterogeneous fleets),
//!   processing batch starts and batch completions in global
//!   simulated-time order. One lane per fleet device
//!   ([`crate::fleet::Topology`]); a pair-built dispatcher maps edge to
//!   lane 0 and cloud to lane 1, bit-identically to the historical
//!   two-lane implementation.
//!
//! The queue-aware decision is then eq. 1 with a wait term on each side
//! ([`crate::coordinator::Router::decide_loaded`]):
//!
//! ```text
//! d = edge  if  T̂_exe,e + Ŵ_e  ≤  T̂_tx + T̂_exe,c + Ŵ_c  else cloud
//! ```
//!
//! When that comparison lands inside a configurable error bar the
//! dispatcher can *hedge* — run the request on both lanes and keep the
//! first finisher ([`Dispatcher::submit_hedged`], wasted work accounting
//! in [`HedgeStats`]); and the models behind the estimates can be refit
//! online from observed completions ([`crate::predictor::RlsPlane`] for
//! the T_exe planes, [`crate::predictor::RlsLine`] for the
//! payload-size → T_tx law) so the decision tracks drifting hardware
//! and networks.
//!
//! The hot path is **zero-churn**: admission queues sit on ring buffers
//! ([`crate::util::RingBuffer`]), in-flight hedge races live in a
//! generational slab arena ([`crate::util::Slab`]) keyed directly from
//! the queued records, batches form into a reused scratch buffer, and
//! the pending-completion heap stores `Copy` entries — once warmed, the
//! steady-state dispatch path performs no heap allocation and no
//! hashing (asserted by `tests/alloc_steady_state.rs`).
//!
//! [`crate::sim::harness::run_contended`] replays open-loop Poisson
//! arrivals through this subsystem against ground-truth tables
//! (optionally with injected drift), [`crate::sim::harness::run_closed_loop`]
//! drives it with bounded-outstanding closed-loop clients, and
//! [`crate::experiments::load`] sweeps offered load to produce
//! throughput-vs-tail-latency curves per policy.
//!
//! # Example
//!
//! Submit one request and drain it through a fixed-cost executor:
//!
//! ```
//! use cnmt::devices::DeviceKind;
//! use cnmt::scheduler::{BatchExecutor, Dispatcher, DispatcherConfig, QueuedRequest};
//!
//! struct FixedExec;
//! impl BatchExecutor for FixedExec {
//!     fn execute(&mut self, _d: DeviceKind, batch: &[QueuedRequest], _s: f64) -> f64 {
//!         0.1 * batch.len() as f64
//!     }
//! }
//!
//! let mut disp = Dispatcher::new(&DispatcherConfig::default());
//! let rq = QueuedRequest {
//!     id: 0, payload: 0, n: 10, m_est: 9.0,
//!     est_service_s: 0.1, arrival_s: 0.0, bucket: 0, hedge: None,
//! };
//! assert!(disp.submit(DeviceKind::Edge, rq).is_admitted());
//! let mut done = Vec::new();
//! disp.run_until(f64::INFINITY, &mut FixedExec, &mut |c| done.push(c));
//! assert_eq!(done.len(), 1);
//! assert!((done[0].done_s - 0.1).abs() < 1e-12);
//! assert!(disp.idle());
//! ```

pub mod baseline;
pub mod batch;
pub mod capacity;
pub mod dispatch;
pub mod hedge;
pub mod queue;

pub use baseline::BaselineDispatcher;
pub use batch::{BatchPolicy, BatchStats};
pub use capacity::{
    CapacityTracker, BATCH_COST_ALPHA, BATCH_COST_BINS, BATCH_COST_MIN_DISCOUNT,
    BATCH_COST_MIN_OBS,
};
pub use dispatch::{
    BatchExecutor, Completion, CompletionKind, Dispatcher, DispatcherConfig, HedgeOutcome,
    HedgeStats, LaneExecutor, LaneHedgeOutcome, LaneSpec, RetryPolicy,
};
pub use hedge::{
    HedgeBudget, HEDGE_GAIN, HEDGE_MAX_MARGIN_S, HEDGE_MIN_MARGIN_S,
    HEDGE_WINDOW_DECAY,
};
pub use queue::{
    Admission, AdmissionQueue, FairQueue, QueueStats, QueuedRequest, TenantSpec,
};
