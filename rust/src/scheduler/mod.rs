//! Load-aware scheduling: admission queues, capacity tracking,
//! length-bucketed micro-batching and worker-pool dispatch.
//!
//! The paper routes each request in isolation, assuming an idle edge and
//! an idle cloud (eq. 1). This subsystem supplies everything the router
//! needs to stay optimal when that assumption breaks under heavy
//! traffic:
//!
//! * [`queue`] — per-device bounded admission queues with arrival
//!   timestamps and shed/reject accounting;
//! * [`capacity`] — per-device in-flight tracking that converts queue
//!   contents into an expected queueing-delay estimate using the
//!   [`crate::predictor::TexeModel`] planes;
//! * [`batch`] — length-bucketed micro-batching keyed on the
//!   [`crate::predictor::N2mRegressor`] estimate M̂, amortising the
//!   serial O(M) decode loop across compatible requests;
//! * [`dispatch`] — the two-lane worker-pool dispatcher tying the above
//!   together behind a backend-agnostic [`BatchExecutor`].
//!
//! The queue-aware decision is then eq. 1 with a wait term on each side
//! ([`crate::coordinator::Router::decide_loaded`]):
//!
//! ```text
//! d = edge  if  T̂_exe,e + Ŵ_e  ≤  T̂_tx + T̂_exe,c + Ŵ_c  else cloud
//! ```
//!
//! [`crate::sim::harness::run_contended`] replays open-loop Poisson
//! arrivals through this subsystem against ground-truth tables, and
//! [`crate::experiments::load`] sweeps offered load to produce
//! throughput-vs-tail-latency curves per policy.

pub mod batch;
pub mod capacity;
pub mod dispatch;
pub mod queue;

pub use batch::{BatchPolicy, BatchStats};
pub use capacity::CapacityTracker;
pub use dispatch::{BatchExecutor, Completion, Dispatcher, DispatcherConfig};
pub use queue::{Admission, AdmissionQueue, QueueStats, QueuedRequest};
