//! Self-tuning hedge margin: a waste-budget controller.
//!
//! Hedged dispatch buys tail latency with duplicated work, priced by
//! one knob — the error bar around the eq. 1 margin inside which a
//! request races on both placements. A *fixed* error bar prices that
//! tradeoff blindly: at low load every hedge loser runs to completion
//! (idle lanes start both copies immediately), so a margin tuned for
//! the contended regime burns far more than intended; at high load most
//! losers are cancelled while queued and the same margin wastes almost
//! nothing, leaving tail latency on the table.
//!
//! [`HedgeBudget`] closes the loop: the operator configures a **waste
//! budget** — the acceptable fraction of executed work that produces no
//! result ([`crate::sim::ContendedResult::wasted_frac`]) — and the
//! controller adapts the margin online to spend exactly that budget,
//! whatever the load:
//!
//! ```text
//!             ┌────────────── margin_s ──────────────┐
//!             │                                      ▼
//!      ┌──────┴──────┐   hedge if |margin| ≤ bar   ┌──────────┐
//!      │ controller  │ ◀──────── completions ───── │ dispatch │
//!      └──────┬──────┘   (useful / wasted work)    └──────────┘
//!             │
//!   ŵ  = decayed wasted / (useful + wasted)
//!   err = (budget − ŵ) / budget
//!   margin ← clamp(margin · (1 + gain·err), min, max)
//! ```
//!
//! Every completion (solo or hedged) feeds the decayed work window, so
//! ŵ estimates the *recent* wasted-work fraction with time constant
//! ≈ 1/(1−[`HEDGE_WINDOW_DECAY`]) completions. Under budget the margin
//! grows multiplicatively (hedge more — the budget is there to be
//! spent); over budget it shrinks (with ŵ ≤ 1 the shrink factor is
//! bounded below, so the margin cannot collapse in one step). The
//! controller is shared verbatim by the pair harness
//! ([`crate::sim::run_contended`] / [`crate::sim::run_closed_loop`])
//! and the fleet harness ([`crate::sim::run_fleet`] /
//! [`crate::sim::run_fleet_closed`]): plain arithmetic, no
//! transcendentals, deterministic, and mirrored operation-for-operation
//! by the python lockstep mirrors.

use crate::{Error, Result};

/// Per-observation multiplicative gain of the margin update.
pub const HEDGE_GAIN: f64 = 0.05;
/// Per-observation decay of the useful/wasted work window (time
/// constant ≈ 500 completions).
pub const HEDGE_WINDOW_DECAY: f64 = 0.998;
/// Margin floor (seconds): the controller may effectively disable
/// hedging but keeps a toehold so it can re-expand when waste falls.
pub const HEDGE_MIN_MARGIN_S: f64 = 1e-4;
/// Margin ceiling (seconds): beyond this the "error bar" story is
/// untenable — racing placements that differ by more is not hedging.
pub const HEDGE_MAX_MARGIN_S: f64 = 0.050;

/// Online margin controller capping the wasted-work fraction
/// ([`crate::sim::ContendedResult::wasted_frac`]).
#[derive(Debug, Clone, Copy)]
pub struct HedgeBudget {
    budget_frac: f64,
    margin_s: f64,
    useful_s: f64,
    wasted_s: f64,
}

impl HedgeBudget {
    /// Controller targeting `budget_frac` of executed work as waste,
    /// starting from `init_margin_s` (clamped into the margin bounds).
    /// `budget_frac` must sit in (0, 1) — 0 means "never hedge" (just
    /// disable hedging instead) and 1 means "all work may be waste".
    pub fn new(budget_frac: f64, init_margin_s: f64) -> Result<HedgeBudget> {
        if !(budget_frac.is_finite() && budget_frac > 0.0 && budget_frac < 1.0) {
            return Err(Error::Config(format!(
                "hedge waste budget {budget_frac} outside (0, 1)"
            )));
        }
        if !(init_margin_s.is_finite() && init_margin_s > 0.0) {
            return Err(Error::Config(format!(
                "hedge initial margin {init_margin_s} must be finite and > 0"
            )));
        }
        Ok(HedgeBudget {
            budget_frac,
            margin_s: init_margin_s.clamp(HEDGE_MIN_MARGIN_S, HEDGE_MAX_MARGIN_S),
            useful_s: 0.0,
            wasted_s: 0.0,
        })
    }

    /// The current hedge error bar (seconds).
    pub fn margin_s(&self) -> f64 {
        self.margin_s
    }

    /// The configured waste budget (fraction of executed work).
    pub fn budget_frac(&self) -> f64 {
        self.budget_frac
    }

    /// The decayed-window wasted-work fraction the controller currently
    /// sees (0 before any observation).
    pub fn observed_frac(&self) -> f64 {
        let total = self.useful_s + self.wasted_s;
        if total > 0.0 {
            self.wasted_s / total
        } else {
            0.0
        }
    }

    /// The decayed useful-work window (seconds). Logged with every
    /// margin adjustment so the offline trace verifier can replay the
    /// control law and invert the window back to raw work.
    pub fn useful_s(&self) -> f64 {
        self.useful_s
    }

    /// The decayed wasted-work window (seconds); see [`Self::useful_s`].
    pub fn wasted_s(&self) -> f64 {
        self.wasted_s
    }

    /// Feed one completed execution: its true work content `t_s`
    /// (standalone execution seconds — the same unit the harness's
    /// waste accounting uses) and whether it was wasted (a hedge loser)
    /// or useful (a result). Updates the window and adjusts the margin.
    /// O(1), plain arithmetic.
    pub fn observe(&mut self, t_s: f64, wasted: bool) {
        if !(t_s.is_finite() && t_s >= 0.0) {
            return; // never poison the window
        }
        self.useful_s *= HEDGE_WINDOW_DECAY;
        self.wasted_s *= HEDGE_WINDOW_DECAY;
        if wasted {
            self.wasted_s += t_s;
        } else {
            self.useful_s += t_s;
        }
        let total = self.useful_s + self.wasted_s;
        if total > 0.0 {
            let frac = self.wasted_s / total;
            let err = (self.budget_frac - frac) / self.budget_frac;
            self.margin_s = (self.margin_s * (1.0 + HEDGE_GAIN * err))
                .clamp(HEDGE_MIN_MARGIN_S, HEDGE_MAX_MARGIN_S);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn margin_shrinks_over_budget_and_grows_under() {
        let mut ctl = HedgeBudget::new(0.10, 0.010).unwrap();
        // All waste: far over budget, the margin must fall.
        for _ in 0..200 {
            ctl.observe(0.05, true);
        }
        assert!(ctl.margin_s() < 0.010, "margin {} did not shrink", ctl.margin_s());
        assert!(ctl.observed_frac() > 0.9);
        // All useful: under budget, the margin re-expands toward the cap.
        for _ in 0..4000 {
            ctl.observe(0.05, false);
        }
        assert!(
            ctl.margin_s() > 0.010,
            "margin {} did not recover",
            ctl.margin_s()
        );
        assert!(ctl.observed_frac() < 0.05);
    }

    #[test]
    fn margin_stays_clamped() {
        let mut ctl = HedgeBudget::new(0.10, 0.010).unwrap();
        for _ in 0..100_000 {
            ctl.observe(0.05, false);
        }
        assert_eq!(ctl.margin_s(), HEDGE_MAX_MARGIN_S, "no growth past the cap");
        for _ in 0..100_000 {
            ctl.observe(0.05, true);
        }
        assert_eq!(ctl.margin_s(), HEDGE_MIN_MARGIN_S, "no shrink past the floor");
        // The floor keeps a toehold: recovery is still possible.
        for _ in 0..100_000 {
            ctl.observe(0.05, false);
        }
        assert!(ctl.margin_s() > HEDGE_MIN_MARGIN_S);
    }

    #[test]
    fn settles_near_the_budget_under_a_responsive_plant() {
        // Close the loop against a toy plant where hedge propensity is
        // proportional to the margin: waste per observation ∝ margin.
        // The controller must settle with the observed fraction inside
        // a couple of points of the budget.
        let budget = 0.12;
        let mut ctl = HedgeBudget::new(budget, 0.001).unwrap();
        for i in 0..30_000 {
            // Plant: at margin m, a fraction (m / MAX) of work is wasted.
            let waste_p = ctl.margin_s() / HEDGE_MAX_MARGIN_S;
            // Deterministic low-discrepancy dither instead of rng (997
            // is coprime with 1000, so waste spreads evenly in time).
            let wasted = ((i * 997) % 1000) as f64 < waste_p * 1000.0;
            ctl.observe(0.02, wasted);
        }
        let w = ctl.observed_frac();
        assert!(
            (w - budget).abs() < 0.02,
            "settled at {w}, budget {budget}"
        );
    }

    #[test]
    fn init_margin_is_clamped_and_bad_configs_rejected() {
        let ctl = HedgeBudget::new(0.10, 10.0).unwrap();
        assert_eq!(ctl.margin_s(), HEDGE_MAX_MARGIN_S);
        assert_eq!(ctl.budget_frac(), 0.10);
        assert!(HedgeBudget::new(0.0, 0.01).is_err());
        assert!(HedgeBudget::new(1.0, 0.01).is_err());
        assert!(HedgeBudget::new(f64::NAN, 0.01).is_err());
        assert!(HedgeBudget::new(0.1, 0.0).is_err());
        assert!(HedgeBudget::new(0.1, f64::INFINITY).is_err());
        // Non-finite observations are ignored.
        let mut ctl = HedgeBudget::new(0.10, 0.010).unwrap();
        ctl.observe(f64::NAN, true);
        ctl.observe(-1.0, true);
        assert_eq!(ctl.observed_frac(), 0.0);
        assert_eq!(ctl.margin_s(), 0.010);
    }
}
