//! Per-device admission queue: bounded depth, arrival timestamps, and
//! shed/reject accounting.
//!
//! The queue is FIFO in arrival order. Admission control is a hard
//! depth bound — an open-loop arrival process (millions of end-nodes
//! don't slow down because the gateway is busy) must shed load somewhere,
//! and shedding at admission keeps the tail latency of *admitted*
//! requests bounded instead of letting every request rot in an unbounded
//! backlog. Rejected requests are counted, never silently dropped.
//!
//! Storage is a [`RingBuffer`]: O(1) admit/pop with zero steady-state
//! allocation (the slot array only grows past the all-time peak depth,
//! so a warmed queue never touches the allocator — asserted by the
//! counting-allocator test in `tests/alloc_steady_state.rs`). All
//! head operations are O(1); the batcher ([`crate::scheduler::batch`])
//! is the only component that touches non-head elements, under a
//! bounded lookahead window.

use crate::util::{RingBuffer, SlabKey};

/// One request as the scheduler sees it. `payload` is an opaque index
/// into the caller's own request table (ground truth in simulation, the
/// pending-job slab in a real gateway) so the scheduler never owns
/// request bodies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedRequest {
    /// Caller-assigned request id (hedge twins share it).
    pub id: u64,
    /// Index into the caller's request/ground-truth table.
    pub payload: usize,
    /// Source length (tokens).
    pub n: usize,
    /// Scheduler-side output-length estimate M̂ (drives length
    /// bucketing; [`crate::predictor::N2mRegressor`]).
    pub m_est: f64,
    /// Estimated service time on the assigned device (seconds), from
    /// the device's [`crate::predictor::TexeModel`] plane. Drives the
    /// capacity tracker's backlog estimate.
    pub est_service_s: f64,
    /// Arrival time on the scheduler clock (seconds).
    pub arrival_s: f64,
    /// Length bucket (assigned by the batch policy at submission).
    pub bucket: usize,
    /// Slab key of the in-flight hedge entry when this copy is half of
    /// a hedged pair — owned by the dispatcher (`None` for solo
    /// submissions; callers leave it `None`). Replaces the old id-keyed
    /// hash lookups on every completion/cancel with a direct,
    /// generation-checked arena access.
    pub hedge: Option<SlabKey>,
}

/// Outcome of offering a request to the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admitted; `depth` is the queue depth after insertion.
    Admitted {
        /// Queue depth right after this insertion.
        depth: usize,
    },
    /// Shed at admission: the queue was at its depth bound.
    Rejected,
}

impl Admission {
    /// Was the request admitted?
    pub fn is_admitted(&self) -> bool {
        matches!(self, Admission::Admitted { .. })
    }
}

/// Counters the queue maintains (cheap enough to keep always-on).
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueStats {
    /// Requests offered (admitted + rejected).
    pub offered: u64,
    /// Requests admitted.
    pub admitted: u64,
    /// Requests shed at admission (depth bound hit).
    pub rejected: u64,
    /// High-water mark of the queue depth.
    pub peak_depth: usize,
}

/// Bounded FIFO admission queue for one device.
#[derive(Debug, Clone)]
pub struct AdmissionQueue {
    items: RingBuffer<QueuedRequest>,
    max_depth: usize,
    /// Entries known to be cancelled (hedge twins that lost) but not
    /// yet physically removed — they are purged lazily and never run,
    /// so they must not consume admission slots.
    dead: usize,
    stats: QueueStats,
}

impl AdmissionQueue {
    /// `max_depth` is the admission bound (must be > 0).
    pub fn new(max_depth: usize) -> Self {
        assert!(max_depth > 0, "AdmissionQueue needs max_depth > 0");
        AdmissionQueue {
            items: RingBuffer::with_capacity(max_depth.min(1024)),
            max_depth,
            dead: 0,
            stats: QueueStats::default(),
        }
    }

    /// Does the queue have a free admission slot? (Same predicate
    /// [`offer`](AdmissionQueue::offer) applies — the dispatcher uses it
    /// to decide hedging atomically across both lanes.)
    pub fn has_room(&self) -> bool {
        self.live_depth() < self.max_depth
    }

    /// Offer a request: O(1) admit-or-shed. The admission bound counts
    /// only *live* entries — cancelled twins awaiting lazy purge do not
    /// occupy slots.
    pub fn offer(&mut self, rq: QueuedRequest) -> Admission {
        self.stats.offered += 1;
        if !self.has_room() {
            self.stats.rejected += 1;
            return Admission::Rejected;
        }
        self.items.push_back(rq);
        self.stats.admitted += 1;
        let depth = self.live_depth();
        self.stats.peak_depth = self.stats.peak_depth.max(depth);
        Admission::Admitted { depth }
    }

    /// A queued entry was cancelled (it will be lazily purged, never
    /// run): release its admission slot immediately.
    pub fn mark_dead(&mut self) {
        self.dead += 1;
    }

    /// A cancelled entry was physically purged from the queue.
    pub fn unmark_dead(&mut self) {
        self.dead = self.dead.saturating_sub(1);
    }

    /// Entries that still count against the admission bound.
    pub fn live_depth(&self) -> usize {
        self.items.len().saturating_sub(self.dead)
    }

    /// The head request, if any.
    #[inline]
    pub fn peek(&self) -> Option<&QueuedRequest> {
        self.items.front()
    }

    /// Remove and return the head request.
    #[inline]
    pub fn pop(&mut self) -> Option<QueuedRequest> {
        self.items.pop_front()
    }

    /// Element at position `i` from the front (batcher lookahead).
    #[inline]
    pub fn get(&self, i: usize) -> Option<&QueuedRequest> {
        self.items.get(i)
    }

    /// Remove the element at position `i` from the front, preserving the
    /// relative order of the rest. O(i) — callers keep `i` bounded.
    pub fn remove(&mut self, i: usize) -> Option<QueuedRequest> {
        self.items.remove(i)
    }

    /// Drain every queued entry (live and dead alike) into `out` in
    /// FIFO order and reset the dead count — the queue contents are
    /// gone, as when the device crashes ([`crate::sim::FaultSpec`]).
    /// Counters (`stats`) survive: the crash loses requests, not
    /// history.
    pub fn wipe_into(&mut self, out: &mut Vec<QueuedRequest>) {
        while let Some(rq) = self.items.pop_front() {
            out.push(rq);
        }
        self.dead = 0;
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.items.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The admission bound.
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    /// Counter snapshot.
    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    /// Waiting time of the oldest queued request at `now_s` (0 if empty).
    pub fn oldest_wait_s(&self, now_s: f64) -> f64 {
        self.items
            .front()
            .map_or(0.0, |rq| (now_s - rq.arrival_s).max(0.0))
    }
}

// -------------------------------------------------------------- multi-tenant

/// Admission policy for one tenant of a [`FairQueue`].
#[derive(Debug, Clone, Copy)]
pub struct TenantSpec {
    /// Service share weight (> 0): tenant `i` receives `wᵢ / Σw` of the
    /// pops whenever it is backlogged.
    pub weight: f64,
    /// Admission quota (> 0): at most this many of the tenant's
    /// requests may be queued at once; excess offers are shed.
    pub quota: usize,
}

impl TenantSpec {
    /// Equal-weight spec with the given quota.
    pub fn with_quota(quota: usize) -> TenantSpec {
        TenantSpec { weight: 1.0, quota }
    }
}

/// One tenant's sub-queue inside a [`FairQueue`].
#[derive(Debug, Clone)]
struct TenantLane {
    items: RingBuffer<QueuedRequest>,
    /// Absolute deadline (s) of each queued request, parallel to
    /// `items` (same push/pop/remove discipline keeps the rings in
    /// lockstep). FIFO offers store `f64::INFINITY`, so a queue that
    /// never sees a deadline extracts in arrival order even in EDF
    /// mode — the strict `<` scan below keeps the head on ties.
    deadlines: RingBuffer<f64>,
    weight: f64,
    quota: usize,
    /// Smooth-WRR credit: raised by `weight` on every contested pop,
    /// drained by the total active weight when this tenant wins.
    credit: f64,
    stats: QueueStats,
}

/// Multi-tenant admission queue: per-tenant quotas plus weighted fair
/// popping (ROADMAP "multi-tenant fairness").
///
/// A single shared FIFO lets one chatty tenant fill the queue and
/// starve everyone behind it. The fair queue gives each tenant its own
/// bounded sub-queue (the **quota** — a chatty tenant sheds its own
/// overflow instead of consuming the shared bound) and pops across
/// tenants by **smooth weighted round-robin**: on every pop each
/// backlogged tenant's credit grows by its weight, the highest credit
/// wins (lowest tenant id on ties) and pays the total active weight
/// back. Deterministic, O(tenants) per pop, allocation-free once the
/// sub-queues are warm.
///
/// Starvation bound: while tenant `i` stays backlogged it wins at least
/// `⌊k·wᵢ/Σw⌋` of any `k` consecutive pops — a flood from another
/// tenant changes *what* the flooder gets, never whether `i` is served
/// (the starvation unit test drives a 100:1 flood and asserts the
/// trickle tenant's service interleaves throughout).
#[derive(Debug, Clone)]
pub struct FairQueue {
    tenants: Vec<TenantLane>,
    /// Intra-tenant extraction order: FIFO (`false`, the classic
    /// behaviour) or earliest-deadline-first (`true`). EDF reorders
    /// only *within* a tenant's own sub-queue — the WRR choice of
    /// which tenant pops next, and every quota, is unchanged, so a
    /// tenant's deadlines can never displace a neighbour's share.
    edf: bool,
}

impl FairQueue {
    /// One sub-queue per tenant spec. Panics on an empty spec list or a
    /// degenerate weight/quota (misconfiguration, not runtime input).
    pub fn new(specs: &[TenantSpec]) -> FairQueue {
        FairQueue::with_order(specs, false)
    }

    /// Like [`FairQueue::new`], but extracting each tenant's requests
    /// earliest-deadline-first ([`FairQueue::offer_deadline`]) instead
    /// of FIFO. Requests offered without a deadline carry `+∞` and so
    /// fall back to arrival order among themselves.
    pub fn new_edf(specs: &[TenantSpec]) -> FairQueue {
        FairQueue::with_order(specs, true)
    }

    fn with_order(specs: &[TenantSpec], edf: bool) -> FairQueue {
        assert!(!specs.is_empty(), "FairQueue needs at least one tenant");
        FairQueue {
            tenants: specs
                .iter()
                .map(|s| {
                    assert!(
                        s.weight.is_finite() && s.weight > 0.0,
                        "tenant weight must be finite and > 0"
                    );
                    assert!(s.quota > 0, "tenant quota must be > 0");
                    TenantLane {
                        items: RingBuffer::with_capacity(s.quota.min(1024)),
                        deadlines: RingBuffer::with_capacity(s.quota.min(1024)),
                        weight: s.weight,
                        quota: s.quota,
                        credit: 0.0,
                        stats: QueueStats::default(),
                    }
                })
                .collect(),
            edf,
        }
    }

    /// Number of tenants.
    pub fn tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Offer a request on behalf of `tenant`: admitted unless the
    /// tenant's quota is exhausted. Another tenant's backlog can never
    /// cause the rejection — that is the quota's whole point.
    pub fn offer(&mut self, tenant: usize, rq: QueuedRequest) -> Admission {
        self.offer_deadline(tenant, rq, f64::INFINITY)
    }

    /// Offer a request carrying an absolute latency deadline (s). In an
    /// EDF queue ([`FairQueue::new_edf`]) the deadline orders the
    /// request within its tenant's sub-queue; in a FIFO queue it is
    /// recorded but never consulted. Admission is identical to
    /// [`FairQueue::offer`] — deadlines affect order, never quota.
    pub fn offer_deadline(
        &mut self,
        tenant: usize,
        rq: QueuedRequest,
        deadline_s: f64,
    ) -> Admission {
        let lane = &mut self.tenants[tenant];
        lane.stats.offered += 1;
        if lane.items.len() >= lane.quota {
            lane.stats.rejected += 1;
            return Admission::Rejected;
        }
        lane.items.push_back(rq);
        lane.deadlines.push_back(deadline_s);
        lane.stats.admitted += 1;
        let depth = lane.items.len();
        lane.stats.peak_depth = lane.stats.peak_depth.max(depth);
        Admission::Admitted { depth }
    }

    /// Pop the next request under smooth weighted round-robin; returns
    /// the owning tenant alongside it. O(tenants), plus an O(depth)
    /// deadline scan of the winning tenant in EDF mode. The WRR winner
    /// is chosen *before* looking at deadlines, so EDF can never move
    /// service between tenants — only reorder a tenant's own backlog.
    pub fn pop(&mut self) -> Option<(usize, QueuedRequest)> {
        let mut total = 0.0f64;
        for lane in &self.tenants {
            if !lane.items.is_empty() {
                total += lane.weight;
            }
        }
        if total == 0.0 {
            return None;
        }
        let mut winner = usize::MAX;
        let mut best = f64::NEG_INFINITY;
        for (i, lane) in self.tenants.iter_mut().enumerate() {
            if lane.items.is_empty() {
                continue;
            }
            lane.credit += lane.weight;
            if lane.credit > best {
                best = lane.credit;
                winner = i;
            }
        }
        let edf = self.edf;
        let lane = &mut self.tenants[winner];
        lane.credit -= total;
        let rq = if edf {
            // Earliest deadline wins; strict `<` keeps the earliest
            // *arrival* among equal deadlines (and keeps plain FIFO
            // behaviour when every deadline is the +∞ sentinel).
            let mut best_i = 0usize;
            let mut best_d = *lane.deadlines.get(0).expect("winner lane is non-empty");
            for i in 1..lane.items.len() {
                let d = *lane.deadlines.get(i).expect("deadline ring tracks items");
                if d < best_d {
                    best_d = d;
                    best_i = i;
                }
            }
            lane.deadlines.remove(best_i);
            lane.items.remove(best_i).expect("scanned index is in range")
        } else {
            lane.deadlines.remove(0);
            lane.items.pop_front().expect("winner lane is non-empty")
        };
        Some((winner, rq))
    }

    /// Queued requests across all tenants.
    pub fn depth(&self) -> usize {
        self.tenants.iter().map(|l| l.items.len()).sum()
    }

    /// Queued requests of one tenant.
    pub fn depth_of(&self, tenant: usize) -> usize {
        self.tenants[tenant].items.len()
    }

    /// Is every sub-queue empty?
    pub fn is_empty(&self) -> bool {
        self.tenants.iter().all(|l| l.items.is_empty())
    }

    /// Admission counters of one tenant.
    pub fn stats_of(&self, tenant: usize) -> QueueStats {
        self.tenants[tenant].stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rq(id: u64, arrival_s: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            payload: id as usize,
            n: 10,
            m_est: 10.0,
            est_service_s: 0.05,
            arrival_s,
            bucket: 0,
            hedge: None,
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = AdmissionQueue::new(8);
        for i in 0..5 {
            assert!(q.offer(rq(i, i as f64)).is_admitted());
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap().id, i);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn depth_bound_sheds_and_counts() {
        let mut q = AdmissionQueue::new(3);
        for i in 0..5 {
            q.offer(rq(i, 0.0));
        }
        assert_eq!(q.depth(), 3);
        let s = q.stats();
        assert_eq!(s.offered, 5);
        assert_eq!(s.admitted, 3);
        assert_eq!(s.rejected, 2);
        assert_eq!(s.peak_depth, 3);
        // Shedding frees no slots; popping does.
        assert!(!q.has_room());
        q.pop();
        assert!(q.has_room());
        assert!(q.offer(rq(9, 1.0)).is_admitted());
    }

    #[test]
    fn oldest_wait_tracks_head() {
        let mut q = AdmissionQueue::new(4);
        assert_eq!(q.oldest_wait_s(10.0), 0.0);
        q.offer(rq(0, 2.0));
        q.offer(rq(1, 3.0));
        assert!((q.oldest_wait_s(10.0) - 8.0).abs() < 1e-12);
        q.pop();
        assert!((q.oldest_wait_s(10.0) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn remove_preserves_relative_order() {
        let mut q = AdmissionQueue::new(8);
        for i in 0..4 {
            q.offer(rq(i, 0.0));
        }
        let taken = q.remove(1).unwrap();
        assert_eq!(taken.id, 1);
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(rest, vec![0, 2, 3]);
    }

    #[test]
    fn dead_entries_release_admission_slots() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.offer(rq(0, 0.0)).is_admitted());
        assert!(q.offer(rq(1, 0.0)).is_admitted());
        // Physically full, but one entry is cancelled: a slot frees up.
        assert!(!q.offer(rq(2, 0.0)).is_admitted());
        q.mark_dead();
        assert_eq!(q.live_depth(), 1);
        assert!(q.offer(rq(3, 0.0)).is_admitted());
        assert_eq!(q.depth(), 3, "ghost still physically present");
        assert!(!q.offer(rq(4, 0.0)).is_admitted());
        // Purging the ghost keeps live accounting consistent.
        q.pop();
        q.unmark_dead();
        assert_eq!(q.live_depth(), q.depth());
    }

    #[test]
    fn sustained_churn_never_regrows_the_ring() {
        // Steady state: depth oscillates below the peak, so the ring's
        // physical capacity must freeze after the first warm cycle.
        let mut q = AdmissionQueue::new(512);
        for i in 0..64 {
            q.offer(rq(i, 0.0));
        }
        let mut id = 64u64;
        for _ in 0..10_000 {
            q.pop();
            q.offer(rq(id, 0.0));
            id += 1;
        }
        assert_eq!(q.depth(), 64);
        // FIFO order survived the churn.
        assert_eq!(q.peek().unwrap().id, id - 64);
    }

    #[test]
    #[should_panic]
    fn zero_depth_rejected_at_construction() {
        AdmissionQueue::new(0);
    }

    // -------------------------------------------------------- fair queue

    #[test]
    fn fair_queue_quota_bounds_each_tenant_independently() {
        let mut q = FairQueue::new(&[TenantSpec::with_quota(2), TenantSpec::with_quota(4)]);
        for i in 0..5 {
            q.offer(0, rq(i, 0.0));
        }
        // Tenant 0 is clamped at its quota...
        assert_eq!(q.depth_of(0), 2);
        assert_eq!(q.stats_of(0).rejected, 3);
        // ...and its flood cannot shed tenant 1's offers.
        for i in 0..4 {
            assert!(q.offer(1, rq(100 + i, 0.0)).is_admitted());
        }
        assert!(!q.offer(1, rq(104, 0.0)).is_admitted());
        assert_eq!(q.depth(), 6);
    }

    #[test]
    fn fair_queue_pop_respects_weights() {
        // Weights 3:1 over permanently-backlogged tenants: every window
        // of 4 pops serves tenant 0 exactly 3 times.
        let mut q = FairQueue::new(&[
            TenantSpec { weight: 3.0, quota: 64 },
            TenantSpec { weight: 1.0, quota: 64 },
        ]);
        for i in 0..32 {
            q.offer(0, rq(i, 0.0));
            q.offer(1, rq(1000 + i, 0.0));
        }
        let owners: Vec<usize> = (0..32).map(|_| q.pop().unwrap().0).collect();
        for w in owners.chunks(4) {
            assert_eq!(w.iter().filter(|&&t| t == 0).count(), 3, "window {w:?}");
            assert_eq!(w.iter().filter(|&&t| t == 1).count(), 1, "window {w:?}");
        }
    }

    #[test]
    fn fair_queue_fifo_within_tenant() {
        let mut q = FairQueue::new(&[TenantSpec::with_quota(8); 2]);
        for i in 0..4 {
            q.offer(0, rq(i, i as f64));
        }
        let mut last = None;
        while let Some((t, r)) = q.pop() {
            assert_eq!(t, 0);
            if let Some(prev) = last {
                assert!(r.id > prev, "FIFO order violated within tenant");
            }
            last = Some(r.id);
        }
    }

    #[test]
    fn chatty_tenant_cannot_starve_the_trickle_tenant() {
        // THE starvation test (ROADMAP): tenant 0 floods 100 requests,
        // tenant 1 trickles 8; equal weights. Tenant 1's whole backlog
        // must be served within the first 16 pops — interleaved 1:1 —
        // instead of waiting behind the flood as a shared FIFO would
        // force.
        let mut q = FairQueue::new(&[TenantSpec::with_quota(64), TenantSpec::with_quota(64)]);
        for i in 0..100 {
            q.offer(0, rq(i, 0.0));
        }
        for i in 0..8 {
            assert!(q.offer(1, rq(1000 + i, 0.0)).is_admitted());
        }
        let mut trickle_served = 0usize;
        for pops in 1..=16 {
            let (tenant, _rq) = q.pop().unwrap();
            if tenant == 1 {
                trickle_served += 1;
            }
            // Equal weights ⇒ the trickle tenant is never more than one
            // pop behind its fair share.
            assert!(
                trickle_served + 1 >= pops / 2,
                "tenant 1 starved: {trickle_served} served in {pops} pops"
            );
        }
        assert_eq!(trickle_served, 8, "the full trickle backlog was served");
        // The flood keeps draining afterwards.
        assert_eq!(q.pop().unwrap().0, 0);
    }

    #[test]
    fn fair_queue_idle_tenant_accrues_no_credit() {
        // A tenant idle through 20 pops must not burst ahead when it
        // returns — credit only accrues on contested pops.
        let mut q = FairQueue::new(&[TenantSpec::with_quota(64); 2]);
        for i in 0..20 {
            q.offer(0, rq(i, 0.0));
        }
        for _ in 0..20 {
            assert_eq!(q.pop().unwrap().0, 0);
        }
        for i in 0..4 {
            q.offer(0, rq(100 + i, 0.0));
            q.offer(1, rq(200 + i, 0.0));
        }
        let owners: Vec<usize> = (0..8).map(|_| q.pop().unwrap().0).collect();
        // Strict 1:1 alternation — no stored-up burst for either side.
        for w in owners.chunks(2) {
            assert_eq!(w.iter().filter(|&&t| t == 1).count(), 1, "window {w:?}");
        }
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    #[should_panic]
    fn fair_queue_rejects_zero_weight() {
        FairQueue::new(&[TenantSpec { weight: 0.0, quota: 4 }]);
    }

    #[test]
    fn edf_extracts_earliest_deadline_within_tenant() {
        let mut q = FairQueue::new_edf(&[TenantSpec::with_quota(8)]);
        let deadlines = [0.9, 0.3, 0.7, 0.1, 0.5];
        for (i, &d) in deadlines.iter().enumerate() {
            assert!(q.offer_deadline(0, rq(i as u64, i as f64), d).is_admitted());
        }
        // Ids pop in deadline order, not arrival order.
        let order: Vec<u64> = (0..5).map(|_| q.pop().unwrap().1.id).collect();
        assert_eq!(order, vec![3, 1, 4, 2, 0]);
        assert!(q.pop().is_none());
    }

    #[test]
    fn edf_ties_and_missing_deadlines_fall_back_to_fifo() {
        let mut q = FairQueue::new_edf(&[TenantSpec::with_quota(8)]);
        // Equal deadlines: arrival order (strict `<` keeps the head).
        q.offer_deadline(0, rq(0, 0.0), 1.0);
        q.offer_deadline(0, rq(1, 1.0), 1.0);
        // Deadline-less offers sit behind every finite deadline but
        // keep FIFO among themselves.
        q.offer(0, rq(2, 2.0));
        q.offer(0, rq(3, 3.0));
        let order: Vec<u64> = (0..4).map(|_| q.pop().unwrap().1.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    /// Property: within one tenant, EDF never inverts deadlines — for
    /// every pair of requests popped back to back from the same tenant
    /// while both were queued, the earlier pop's deadline is ≤ the
    /// later's. Driven over a pseudo-random offer/pop schedule across
    /// two tenants so the WRR interleaving is exercised too.
    #[test]
    fn edf_never_inverts_deadlines_within_a_tenant() {
        let mut q = FairQueue::new_edf(&[
            TenantSpec { weight: 3.0, quota: 32 },
            TenantSpec { weight: 1.0, quota: 32 },
        ]);
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        let mut id = 0u64;
        let mut deadline_of = std::collections::HashMap::new();
        for round in 0..200 {
            // A burst of offers with scrambled deadlines...
            for _ in 0..(next() % 4 + 1) {
                let d = (next() % 1000) as f64 / 10.0;
                let tenant = (next() % 2) as usize;
                if q.offer_deadline(tenant, rq(id, round as f64), d).is_admitted() {
                    deadline_of.insert(id, d);
                }
                id += 1;
            }
            // ...then a partial drain, checking per-tenant monotonicity
            // against the set of ids that were co-queued.
            let mut last: [Option<f64>; 2] = [None, None];
            for _ in 0..(next() % 3) {
                let Some((tenant, popped)) = q.pop() else { break };
                let d = deadline_of[&popped.id];
                if let Some(prev) = last[tenant] {
                    assert!(
                        prev <= d,
                        "tenant {tenant} inverted deadlines: {prev} before {d}"
                    );
                }
                last[tenant] = Some(d);
            }
        }
    }

    #[test]
    fn edf_respects_quota_and_wrr_shares() {
        // Deadlines cannot buy admission past the quota, and an urgent
        // tenant still only gets its weighted share of pops.
        let mut q = FairQueue::new_edf(&[TenantSpec::with_quota(2), TenantSpec::with_quota(4)]);
        assert!(q.offer_deadline(0, rq(0, 0.0), 0.001).is_admitted());
        assert!(q.offer_deadline(0, rq(1, 0.0), 0.002).is_admitted());
        // Quota full: the most urgent deadline in the world still sheds.
        assert!(!q.offer_deadline(0, rq(2, 0.0), 1e-9).is_admitted());
        assert_eq!(q.stats_of(0).rejected, 1);
        for i in 0..4 {
            assert!(q.offer_deadline(1, rq(10 + i, 0.0), 100.0).is_admitted());
        }
        // Equal weights: strict alternation while both are backlogged,
        // even though tenant 0 holds every early deadline.
        let owners: Vec<usize> = (0..4).map(|_| q.pop().unwrap().0).collect();
        assert_eq!(owners, vec![0, 1, 0, 1]);
    }
}
