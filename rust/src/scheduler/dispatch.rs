//! Worker-pool dispatcher: drives device executors from the admission
//! queues — N lanes, one per fleet device.
//!
//! One **lane** per device: an [`AdmissionQueue`] plus a
//! [`CapacityTracker`] over a fixed worker pool (an edge gateway is
//! typically 1 worker — one serial execution stream, the discipline the
//! paper's latency model assumes — while a cloud replica exposes
//! several). Historically the dispatcher hard-coded two lanes (edge,
//! cloud); it now holds a `Vec` of lanes indexed by the fleet's device
//! id ([`crate::fleet::DeviceId`]), so the same event loop serves the
//! paper's pair and an N-edge × M-replica topology. The classic
//! edge/cloud surface ([`submit`], [`expected_wait_s`], …) is a thin
//! mapping onto lanes 0 (edge) and 1 (cloud) of a pair-built dispatcher
//! — same structures, same arithmetic, bit-identical behaviour (the
//! differential test against [`crate::scheduler::baseline`] enforces
//! it).
//!
//! The dispatcher is clock-driven and backend-agnostic: it owns *when*
//! and *what* to run, an executor owns *how long* it takes — the
//! simulation backs it with ground-truth tables
//! ([`crate::sim::harness`]), a live gateway would back it with real
//! engines. Two executor traits exist: [`BatchExecutor`] (the classic
//! per-`DeviceKind` surface) and [`LaneExecutor`] (per-lane, what a
//! heterogeneous fleet needs); every `BatchExecutor` is automatically a
//! `LaneExecutor` that ignores the lane index.
//!
//! The event loop is unchanged by the fleet generalisation: batch
//! *starts* (earliest ready batch across all lanes, lowest lane index
//! winning ties — edge before cloud in the pair) and batch *completions*
//! (a min-heap on finish time) are processed in global simulated-time
//! order, completions first on ties. This ordering is what makes
//! cross-lane interactions — a hedge winner on one lane cancelling its
//! twin on another — causally correct: a twin can only be cancelled by a
//! completion that actually precedes its dispatch.
//!
//! ## Hedged dispatch
//!
//! When the router's expected-latency gap between the two candidate
//! placements is inside its error bar, committing to either side is a
//! coin flip; [`submit_hedged_lanes`] instead enqueues a copy on *both*
//! lanes under one request id (in a fleet: the best edge placement races
//! the best cloud placement — [`crate::fleet::select`]). The first copy
//! to **finish** is the request's result ([`CompletionKind::HedgeWin`]);
//! the twin is cancelled. A twin still queued is purged without running
//! and its backlog share reclaimed ([`CapacityTracker::on_cancel`]); a
//! twin already executing runs to completion as wasted work
//! ([`CompletionKind::HedgeLoss`]). [`HedgeStats`] counts every outcome.
//!
//! ## Zero-churn hot path
//!
//! In-flight hedge races live in a generational slab arena
//! ([`crate::util::Slab`]); each queued copy carries its race's
//! [`crate::util::SlabKey`], and the race entry records *which two
//! lanes* it spans, so completion classification and cancellation are
//! direct, generation-checked array accesses whatever the fleet size —
//! no id-keyed `HashMap`, no cancel-token `HashSet`, and a cancelled
//! twin is marked *in* its race entry rather than in a side set. Batches
//! form into a scratch buffer reused across dispatches, the admission
//! queues sit on ring buffers, and the pending-completion min-heap
//! stores `Copy` records — once warmed to its peak population the whole
//! dispatch path performs **zero heap allocations**, asserted by the
//! counting-allocator test in `tests/alloc_steady_state.rs`.
//!
//! The per-request hot path (`expected_wait_lane` → route → [`submit`])
//! is O(1) for a fixed worker pool: no allocation, no queue scans.
//! Dispatch itself ([`run_until`]) is amortised O(lanes + log inflight)
//! per request (lane scan + heap push/pop); cancellation is O(1).
//!
//! [`submit`]: Dispatcher::submit
//! [`submit_hedged_lanes`]: Dispatcher::submit_hedged_lanes
//! [`expected_wait_s`]: Dispatcher::expected_wait_s
//! [`run_until`]: Dispatcher::run_until

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::devices::DeviceKind;
use crate::obs::{Detector, Event as ObsEvent, FlightRecorder};
use crate::util::{Slab, SlabKey};

use super::batch::{BatchPolicy, BatchStats};
use super::capacity::CapacityTracker;
use super::queue::{Admission, AdmissionQueue, FairQueue, QueueStats, QueuedRequest, TenantSpec};

/// Live depth the main admission queue is kept at while a fair
/// front-end is active: deep enough that the batcher's lookahead always
/// has material, shallow enough that the weighted-fair pop order — not
/// FIFO arrival order — decides who runs next. (With a deep
/// pass-through a flood admitted early would still sit in front of a
/// late-arriving trickle tenant.)
const FAIR_PASS_DEPTH: usize = 32;

/// Service-time backend keyed by device *kind*: how long a batch runs on
/// the edge or the cloud. The classic pair surface; heterogeneous fleets
/// implement [`LaneExecutor`] instead (every `BatchExecutor` is one).
pub trait BatchExecutor {
    /// Service seconds for `batch` started at `start_s` on `device`.
    /// `batch` is non-empty.
    fn execute(
        &mut self,
        device: DeviceKind,
        batch: &[QueuedRequest],
        start_s: f64,
    ) -> f64;
}

/// Service-time backend keyed by *lane* (fleet device id): how long a
/// batch runs on a specific device of a heterogeneous topology. The
/// dispatcher's event loop is generic over this trait; the blanket impl
/// below makes every [`BatchExecutor`] a `LaneExecutor` that ignores the
/// lane index, so pair-era executors keep working unchanged.
pub trait LaneExecutor {
    /// Service seconds for `batch` started at `start_s` on lane `lane`
    /// (whose tier is `device`). `batch` is non-empty.
    fn execute_lane(
        &mut self,
        lane: usize,
        device: DeviceKind,
        batch: &[QueuedRequest],
        start_s: f64,
    ) -> f64;
}

impl<E: BatchExecutor> LaneExecutor for E {
    fn execute_lane(
        &mut self,
        _lane: usize,
        device: DeviceKind,
        batch: &[QueuedRequest],
        start_s: f64,
    ) -> f64 {
        self.execute(device, batch, start_s)
    }
}

/// Sizing of one dispatcher lane (one fleet device).
#[derive(Debug, Clone, Copy)]
pub struct LaneSpec {
    /// The device's tier (drives [`Completion::device`] and the
    /// edge/cloud hedge-win accounting).
    pub kind: DeviceKind,
    /// Worker slots on this device.
    pub workers: usize,
    /// Admission-queue depth bound for this lane.
    pub max_queue_depth: usize,
}

/// Dispatcher sizing parameters for the classic edge/cloud pair.
#[derive(Debug, Clone, Copy)]
pub struct DispatcherConfig {
    /// Edge worker slots (the gateway's serial executor ⇒ usually 1).
    pub edge_workers: usize,
    /// Cloud worker slots.
    pub cloud_workers: usize,
    /// Per-device admission-queue depth bound.
    pub max_queue_depth: usize,
    /// Micro-batching policy (shared by both lanes).
    pub batch: BatchPolicy,
    /// Optional multi-tenant admission front-end: number of
    /// equal-weight tenants sharing each lane through a
    /// [`FairQueue`] (0 = the classic shared FIFO). With N tenants,
    /// each gets a per-lane quota of `max_queue_depth / N` — a flooding
    /// tenant sheds its own overflow instead of consuming the shared
    /// bound — and admitted requests drain into the dispatch queue in
    /// smooth weighted-round-robin order via
    /// [`Dispatcher::submit_lane_tenant`]. Solo/hedged submissions
    /// through the tenant-less entry points bypass the front-end.
    pub fair_tenants: usize,
}

impl Default for DispatcherConfig {
    fn default() -> Self {
        DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 4,
            max_queue_depth: 512,
            batch: BatchPolicy::default(),
            fair_tenants: 0,
        }
    }
}

/// Timeout / requeue policy for failover-aware dispatch
/// ([`crate::sim::harness::run_fleet_outage`]).
///
/// Each solo submission arms a queue-wait deadline of
/// `max(timeout_mult × score, min_timeout_s)` where `score` is the
/// selector's winning placement score (estimated wait + service). A
/// fired timeout — or a copy killed by [`Dispatcher::fail_lane`] —
/// requeues through the selector after an exponential backoff of
/// `backoff_base_s × backoff_mult^(attempt-1)`; once a request has
/// burned `max_retries` re-dispatch attempts it is shed permanently.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Deadline as a multiple of the scored placement estimate.
    pub timeout_mult: f64,
    /// Deadline floor (s) so near-zero estimates don't thrash.
    pub min_timeout_s: f64,
    /// First-retry backoff delay (s).
    pub backoff_base_s: f64,
    /// Backoff growth factor per additional attempt.
    pub backoff_mult: f64,
    /// Re-dispatch budget per request before it is shed permanently.
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            timeout_mult: 4.0,
            min_timeout_s: 0.25,
            backoff_base_s: 0.05,
            backoff_mult: 2.0,
            max_retries: 4,
        }
    }
}

impl RetryPolicy {
    /// Structural sanity: multipliers and delays finite and positive.
    pub fn validate(&self) -> crate::Result<()> {
        for (name, v) in [
            ("timeout_mult", self.timeout_mult),
            ("min_timeout_s", self.min_timeout_s),
            ("backoff_base_s", self.backoff_base_s),
            ("backoff_mult", self.backoff_mult),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(crate::Error::Config(format!(
                    "retry {name} {v} must be finite and > 0"
                )));
            }
        }
        Ok(())
    }

    /// The queue-wait deadline armed for a placement scored `score_s`.
    pub fn deadline_after(&self, score_s: f64) -> f64 {
        (self.timeout_mult * score_s).max(self.min_timeout_s)
    }

    /// Backoff delay before re-dispatch attempt `attempt` (1-based).
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        self.backoff_base_s * self.backoff_mult.powi(attempt as i32 - 1)
    }
}

/// How a completed copy relates to its request (hedging outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionKind {
    /// The request's only submission: this completion is its result.
    Solo,
    /// Hedged, and this copy finished first: the request's result. The
    /// twin has been cancelled (purged if still queued).
    HedgeWin,
    /// Hedged, and the twin already won: this copy's work is wasted.
    /// Never count it toward goodput.
    HedgeLoss,
}

impl CompletionKind {
    /// Is this completion the request's result (vs duplicated waste)?
    pub fn is_result(&self) -> bool {
        !matches!(self, CompletionKind::HedgeLoss)
    }
}

/// One completed request copy, reported through [`Dispatcher::run_until`]
/// in nondecreasing `done_s` order.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// The queued request (hedge twins share `id`/`payload`).
    pub request: QueuedRequest,
    /// Tier of the device the copy ran on.
    pub device: DeviceKind,
    /// Lane (fleet device id) the copy ran on — 0 = edge, 1 = cloud for
    /// a pair-built dispatcher.
    pub lane: usize,
    /// When its batch started executing.
    pub start_s: f64,
    /// When its batch finished (= response time at the device).
    pub done_s: f64,
    /// Size of the batch it rode in.
    pub batch_size: usize,
    /// Hedging outcome ([`CompletionKind::Solo`] for normal submissions).
    pub kind: CompletionKind,
}

/// Hedged-dispatch counters kept by the dispatcher.
///
/// Invariants once drained: `wins_edge + wins_cloud == hedged`, and every
/// hedged request resolves its twin exactly one way —
/// `cancelled_unrun + losers_run == hedged`. In a fleet the per-tier win
/// counters aggregate over that tier's lanes.
#[derive(Debug, Clone, Copy, Default)]
pub struct HedgeStats {
    /// Requests actually duplicated on both lanes (both copies admitted).
    pub hedged: u64,
    /// Hedged requests whose edge-tier copy finished first.
    pub wins_edge: u64,
    /// Hedged requests whose cloud-tier copy finished first.
    pub wins_cloud: u64,
    /// Losing twins cancelled while still queued (no work wasted).
    pub cancelled_unrun: u64,
    /// Losing twins that were already executing and ran to completion
    /// (wasted work).
    pub losers_run: u64,
}

/// Outcome of a hedged submission on the classic pair surface
/// ([`Dispatcher::submit_hedged`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HedgeOutcome {
    /// Both copies admitted: the request is racing on both lanes.
    Hedged,
    /// Only one lane had room: degraded to a normal submission there.
    Single(DeviceKind),
    /// Both lanes full: the request was shed.
    Rejected,
}

/// Outcome of a hedged submission across an arbitrary lane pair
/// ([`Dispatcher::submit_hedged_lanes`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneHedgeOutcome {
    /// Both copies admitted: the request is racing on both lanes.
    Hedged,
    /// Only this lane had room: degraded to a normal submission there.
    Single(usize),
    /// Both lanes full: the request was shed.
    Rejected,
}

/// Lifecycle of one hedged copy on its lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CopyState {
    Queued,
    Running,
    Done,
    /// Cancelled while still queued (its twin won): a ghost awaiting
    /// lazy purge. Marked here, in the race entry itself — there is no
    /// side table of cancel tokens to hash into.
    Cancelled,
}

/// Dispatcher-side state of one in-flight hedge race (a slab entry;
/// both queued copies carry its key). `lanes` records which two lanes
/// the race spans — `[0, 1]` for the classic pair, any (edge, cloud)
/// placement pair in a fleet — and `est`/`state` are indexed by *side*
/// (position in `lanes`), not by lane id.
#[derive(Debug, Clone, Copy)]
struct HedgeEntry {
    /// The two lanes racing (side 0, side 1).
    lanes: [usize; 2],
    /// Per-side service estimate — needed to reclaim backlog when the
    /// queued twin is cancelled.
    est: [f64; 2],
    state: [CopyState; 2],
    /// Winning side (0 or 1), once decided.
    winner: Option<u8>,
}

impl HedgeEntry {
    /// Which side of this race lane `lane` is. A live copy is only ever
    /// queued on one of the race's two lanes, so the fallback to side 1
    /// is exact.
    #[inline]
    fn side_of(&self, lane: usize) -> usize {
        if self.lanes[0] == lane {
            0
        } else {
            1
        }
    }
}

/// A dispatched copy waiting for its finish event to fire. Ordered by
/// `(done_s, seq)` — `seq` makes equal finish times resolve in dispatch
/// order, deterministically.
#[derive(Debug, Clone, Copy)]
struct Pending {
    done_s: f64,
    seq: u64,
    start_s: f64,
    batch_size: usize,
    lane: usize,
    request: QueuedRequest,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.done_s == other.done_s && self.seq == other.seq
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.done_s
            .total_cmp(&other.done_s)
            .then(self.seq.cmp(&other.seq))
    }
}

/// One armed queue-wait deadline timer ([`Dispatcher::arm_timeout`]).
/// Ordered by `(deadline_s, seq)` so equal deadlines fire in arming
/// order, deterministically. Entries are lazily stale: dispatching or
/// re-arming a request leaves its old heap entry behind, and
/// [`Dispatcher::fire_timeouts`] discards entries whose `(seq, lane)`
/// no longer match the armed table — the same lazy-invalidations idiom
/// as the hedge ghost purge.
#[derive(Debug, Clone, Copy)]
struct TimerEntry {
    deadline_s: f64,
    seq: u64,
    id: u64,
    lane: usize,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline_s == other.deadline_s && self.seq == other.seq
    }
}

impl Eq for TimerEntry {}

impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.deadline_s
            .total_cmp(&other.deadline_s)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Queue + capacity state for one device (internal to the dispatcher).
#[derive(Debug, Clone)]
struct Lane {
    kind: DeviceKind,
    queue: AdmissionQueue,
    tracker: CapacityTracker,
    /// Multi-tenant admission front-end
    /// ([`DispatcherConfig::fair_tenants`]); requests admitted here are
    /// pumped into `queue` in weighted-fair order as dispatch slots
    /// free up.
    fair: Option<FairQueue>,
    /// Fault-injection state ([`Dispatcher::fail_lane`]): a down lane
    /// refuses admissions and never dispatches until
    /// [`Dispatcher::recover_lane`]. Always `false` unless a
    /// [`crate::sim::FaultSpec`] drives it, so the happy path is
    /// untouched.
    down: bool,
}

impl Lane {
    fn new(kind: DeviceKind, workers: usize, max_depth: usize) -> Self {
        Lane {
            kind,
            queue: AdmissionQueue::new(max_depth),
            tracker: CapacityTracker::new(workers),
            fair: None,
            down: false,
        }
    }

    /// Does this lane accept admissions right now? (The queue-room
    /// predicate, gated on device health.)
    fn has_room(&self) -> bool {
        !self.down && self.queue.has_room()
    }

    /// Admit + account in one step. A down lane refuses outright — the
    /// caller sees the same [`Admission::Rejected`] a full queue
    /// produces.
    fn offer(&mut self, rq: QueuedRequest) -> Admission {
        if self.down {
            return Admission::Rejected;
        }
        let admission = self.queue.offer(rq);
        if admission.is_admitted() {
            self.tracker.on_admit(rq.est_service_s);
        }
        admission
    }

    /// Drain the fair front-end into the dispatch queue (weighted-fair
    /// order) up to the pass-through depth. Capacity was accounted at
    /// front-end admission, so the move itself is accounting-neutral.
    fn pump_fair(&mut self) {
        let Some(fair) = self.fair.as_mut() else { return };
        while self.queue.live_depth() < FAIR_PASS_DEPTH && self.queue.has_room() {
            match fair.pop() {
                Some((_tenant, rq)) => {
                    let admitted = self.queue.offer(rq);
                    debug_assert!(
                        admitted.is_admitted(),
                        "pass-through offer below the bound cannot shed"
                    );
                }
                None => return,
            }
        }
    }
}

fn lane_idx(device: DeviceKind) -> usize {
    match device {
        DeviceKind::Edge => 0,
        DeviceKind::Cloud => 1,
    }
}

/// Is `rq` a cancelled hedge ghost on lane `lane`? (Generation-checked
/// arena lookup; false for solo requests and live copies.)
fn is_ghost(hedges: &Slab<HedgeEntry>, rq: &QueuedRequest, lane: usize) -> bool {
    match rq.hedge {
        Some(key) => matches!(
            hedges.get(key),
            Some(entry) if entry.state[entry.side_of(lane)] == CopyState::Cancelled
        ),
        None => false,
    }
}

/// The N-lane worker-pool dispatcher (lane 0 = edge, lane 1 = cloud
/// when built from a [`DispatcherConfig`] pair).
#[derive(Debug)]
pub struct Dispatcher {
    /// One lane per fleet device, indexed by device id.
    lanes: Vec<Lane>,
    policy: BatchPolicy,
    stats: BatchStats,
    /// Dispatched copies whose finish events have not fired yet
    /// (min-heap on finish time; `Copy` entries, capacity reused).
    pending: BinaryHeap<Reverse<Pending>>,
    seq: u64,
    /// In-flight hedge races (slab arena; keys live in the queued
    /// copies, so no per-completion hashing).
    hedges: Slab<HedgeEntry>,
    /// Scratch buffer batches form into (reused across dispatches).
    scratch: Vec<QueuedRequest>,
    hedge_stats: HedgeStats,
    /// Optional decision-log flight recorder ([`Dispatcher::
    /// attach_recorder`]). One sequence stream covers both the
    /// dispatcher's own events and the ones the harness records through
    /// [`Dispatcher::record`].
    recorder: Option<FlightRecorder>,
    /// Payloads of hedge twins cancelled while still queued — such a
    /// copy never produces a [`Completion`], so streaming callers that
    /// refcount outstanding truths drain this instead
    /// ([`Dispatcher::drain_cancelled_payloads`]). Only populated when
    /// [`Dispatcher::track_cancelled_payloads`] enabled it.
    cancelled_payloads: Vec<usize>,
    track_cancelled: bool,
    /// Armed queue-wait deadline timers, earliest first. Entries can be
    /// stale ([`TimerEntry`]); the heap stays empty unless
    /// [`Dispatcher::enable_timers`] was called and timers were armed.
    timers: BinaryHeap<Reverse<TimerEntry>>,
    /// Monotonic timer generation: each arming gets a fresh value so
    /// stale heap entries are recognisable.
    timer_seq: u64,
    /// Armed-timer table: request id → `(timer seq, lane)` of its live
    /// timer. `None` until [`Dispatcher::enable_timers`] — the happy
    /// path (every legacy harness) never touches timer state, so
    /// behaviour and report bytes are unchanged when no retry policy is
    /// configured.
    armed: Option<std::collections::HashMap<u64, (u64, usize)>>,
    /// Optional online anomaly detector ([`Dispatcher::
    /// attach_detector`]). Observation-only: it taps every completion's
    /// execution residual and drains its alert events into the attached
    /// recorder, but never influences routing. `None` (the default)
    /// keeps the completion path branch-identical to an undetected run.
    detector: Option<Detector>,
}

impl Clone for Dispatcher {
    /// Clones everything but the flight recorder (its streaming sink is
    /// not cloneable, and a cloned dispatcher recording into the
    /// original's log would interleave two runs): the clone starts
    /// unrecorded.
    fn clone(&self) -> Self {
        Dispatcher {
            lanes: self.lanes.clone(),
            policy: self.policy,
            stats: self.stats,
            pending: self.pending.clone(),
            seq: self.seq,
            hedges: self.hedges.clone(),
            scratch: Vec::with_capacity(self.scratch.capacity()),
            hedge_stats: self.hedge_stats,
            recorder: None,
            cancelled_payloads: self.cancelled_payloads.clone(),
            track_cancelled: self.track_cancelled,
            timers: self.timers.clone(),
            timer_seq: self.timer_seq,
            armed: self.armed.clone(),
            // Like the recorder: a clone observing into a copied alert
            // log would double-count; the clone starts undetected.
            detector: None,
        }
    }
}

impl Dispatcher {
    /// Build the classic edge/cloud pair: lane 0 is the edge, lane 1
    /// the cloud. `cfg.fair_tenants > 0` additionally enables the
    /// multi-tenant admission front-end on every lane.
    pub fn new(cfg: &DispatcherConfig) -> Self {
        let mut disp = Dispatcher::with_lanes(
            &[
                LaneSpec {
                    kind: DeviceKind::Edge,
                    workers: cfg.edge_workers,
                    max_queue_depth: cfg.max_queue_depth,
                },
                LaneSpec {
                    kind: DeviceKind::Cloud,
                    workers: cfg.cloud_workers,
                    max_queue_depth: cfg.max_queue_depth,
                },
            ],
            cfg.batch,
        );
        if cfg.fair_tenants > 0 {
            disp.enable_fair_tenants(cfg.fair_tenants);
        }
        disp
    }

    /// Enable the multi-tenant admission front-end on every lane:
    /// `tenants` equal-weight tenants, each with a per-lane quota of
    /// `max_queue_depth / tenants` (at least 1). Submissions then go
    /// through [`Dispatcher::submit_lane_tenant`]; requests drain into
    /// each lane's dispatch queue in smooth weighted-round-robin order,
    /// so a flooding tenant sheds its own overflow and can no longer
    /// push a neighbour's requests behind its backlog.
    pub fn enable_fair_tenants(&mut self, tenants: usize) {
        assert!(tenants > 0, "fair front-end needs at least one tenant");
        for lane in &mut self.lanes {
            let quota = (lane.queue.max_depth() / tenants).max(1);
            lane.fair = Some(FairQueue::new(&vec![TenantSpec::with_quota(quota); tenants]));
        }
    }

    /// Enable the multi-tenant admission front-end with explicit
    /// per-tenant specs (weight + quota each), optionally ordering each
    /// tenant's sub-queue earliest-deadline-first
    /// ([`FairQueue::new_edf`]). The scenario engine maps SLO service
    /// classes onto tenants through this;
    /// [`Dispatcher::enable_fair_tenants`] remains the equal-share FIFO
    /// shorthand and is unchanged.
    pub fn enable_fair_tenants_spec(&mut self, specs: &[TenantSpec], edf: bool) {
        assert!(!specs.is_empty(), "fair front-end needs at least one tenant");
        for lane in &mut self.lanes {
            lane.fair = Some(if edf {
                FairQueue::new_edf(specs)
            } else {
                FairQueue::new(specs)
            });
        }
    }

    /// Turn on the per-batch-size amortisation model in every lane's
    /// capacity tracker ([`CapacityTracker::enable_batch_aware`]):
    /// dispatched batches feed the online fit and the expected-wait
    /// estimate stops pricing backlog as serial work once warmed. Off
    /// by default; legacy runs never touch it.
    pub fn enable_batch_aware_wait(&mut self) {
        for lane in &mut self.lanes {
            lane.tracker.enable_batch_aware();
        }
    }

    /// Build a fleet dispatcher: one lane per device spec, indexed in
    /// order (the fleet's device ids). Panics on an empty spec list —
    /// a dispatcher with no lanes can route nothing.
    pub fn with_lanes(specs: &[LaneSpec], batch: BatchPolicy) -> Self {
        assert!(!specs.is_empty(), "Dispatcher needs at least one lane");
        Dispatcher {
            lanes: specs
                .iter()
                .map(|s| Lane::new(s.kind, s.workers, s.max_queue_depth))
                .collect(),
            policy: batch,
            stats: BatchStats::default(),
            pending: BinaryHeap::with_capacity(64),
            seq: 0,
            hedges: Slab::with_capacity(16),
            scratch: Vec::with_capacity(batch.max_batch.max(1)),
            hedge_stats: HedgeStats::default(),
            recorder: None,
            cancelled_payloads: Vec::new(),
            track_cancelled: false,
            timers: BinaryHeap::new(),
            timer_seq: 0,
            armed: None,
            detector: None,
        }
    }

    /// Attach a decision-log flight recorder: from here on, every
    /// admission, shed, batch, dispatch, completion, and hedge
    /// cancellation is recorded (O(1), allocation-free once the ring is
    /// warm). Replaces any previous recorder.
    pub fn attach_recorder(&mut self, rec: FlightRecorder) {
        self.recorder = Some(rec);
    }

    /// Detach and return the flight recorder, if one is attached.
    pub fn take_recorder(&mut self) -> Option<FlightRecorder> {
        self.recorder.take()
    }

    /// Enable (or disable) recording of cancelled-while-queued hedge
    /// twins' payloads. Off by default: the classic materialized
    /// harness never needs it, and keeping the vector untouched
    /// preserves the steady-state zero-allocation guarantee.
    pub fn track_cancelled_payloads(&mut self, on: bool) {
        self.track_cancelled = on;
    }

    /// Drain the payloads of hedge twins cancelled while still queued
    /// since the last drain. A cancelled-queued copy never surfaces as
    /// a [`Completion`], so a streaming caller releases its truth
    /// window reference here instead.
    pub fn drain_cancelled_payloads(&mut self) -> std::vec::Drain<'_, usize> {
        self.cancelled_payloads.drain(..)
    }

    /// The attached flight recorder, for callers (the harness) that
    /// record placement/control events into the same sequence stream.
    pub fn recorder_mut(&mut self) -> Option<&mut FlightRecorder> {
        self.recorder.as_mut()
    }

    /// Attach an online anomaly detector: from here on, every
    /// completion feeds its lane's execution-residual chart, and any
    /// alert transitions are drained into the attached recorder (if
    /// any) at the observation instant. Observation-only — routing is
    /// untouched. Replaces any previous detector.
    pub fn attach_detector(&mut self, det: Detector) {
        assert_eq!(
            det.num_lanes(),
            self.lanes.len(),
            "detector must cover every dispatcher lane"
        );
        self.detector = Some(det);
    }

    /// Detach and return the anomaly detector, if one is attached.
    pub fn take_detector(&mut self) -> Option<Detector> {
        self.detector.take()
    }

    /// The attached detector, for harness-side taps (transfer
    /// residuals, reroute/timeout evidence, gauge samples).
    pub fn detector_mut(&mut self) -> Option<&mut Detector> {
        self.detector.as_mut()
    }

    /// Drain any alert events the detector has pending into the flight
    /// recorder at time `t_s`. Harness taps that feed the detector
    /// directly call this afterwards so raises land in the decision log
    /// next to the observation that triggered them.
    pub fn drain_alerts(&mut self, t_s: f64) {
        while let Some(ev) = self.detector.as_mut().and_then(|d| d.pop_event()) {
            self.record(t_s, ev);
        }
    }

    /// Record `ev` at sim time `t_s` if a recorder is attached; no-op
    /// (one branch) otherwise.
    #[inline]
    pub fn record(&mut self, t_s: f64, ev: ObsEvent) {
        if let Some(rec) = self.recorder.as_mut() {
            rec.record(t_s, ev);
        }
    }

    /// Number of lanes (fleet devices).
    pub fn num_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Tier of lane `lane`.
    pub fn lane_kind(&self, lane: usize) -> DeviceKind {
        self.lanes[lane].kind
    }

    /// Expected queueing delay on `device` for a request arriving now —
    /// the router adds this to each side of eq. 1. Pair surface (lane 0
    /// = edge, lane 1 = cloud).
    #[inline]
    pub fn expected_wait_s(&self, device: DeviceKind, now_s: f64) -> f64 {
        self.expected_wait_lane(lane_idx(device), now_s)
    }

    /// Expected queueing delay on lane `lane` — the fleet selector adds
    /// this to every candidate placement's score.
    #[inline]
    pub fn expected_wait_lane(&self, lane: usize, now_s: f64) -> f64 {
        self.lanes[lane].tracker.expected_wait_s(now_s)
    }

    /// Admit a request to `device`'s queue (pair surface).
    pub fn submit(&mut self, device: DeviceKind, rq: QueuedRequest) -> Admission {
        self.submit_lane(lane_idx(device), rq)
    }

    /// Admit a request to lane `lane`'s queue (O(1), allocation-free
    /// once warmed). The request's bucket is assigned here so queue and
    /// batcher always agree on it; the hedge key is dispatcher-owned
    /// and cleared for solo submissions.
    pub fn submit_lane(&mut self, lane: usize, mut rq: QueuedRequest) -> Admission {
        rq.bucket = self.policy.bucket_of(rq.m_est);
        rq.hedge = None;
        let admission = self.lanes[lane].offer(rq);
        self.record_admission(&rq, lane, admission.is_admitted());
        admission
    }

    /// Log one solo admission outcome, when a recorder is attached.
    #[inline]
    fn record_admission(&mut self, rq: &QueuedRequest, lane: usize, admitted: bool) {
        if let Some(rec) = self.recorder.as_mut() {
            let ev = if admitted {
                ObsEvent::Admit { id: rq.id, lane: lane as u32, hedged: false }
            } else {
                ObsEvent::Shed { id: rq.id }
            };
            rec.record(rq.arrival_s, ev);
        }
    }

    /// Admit a request to lane `lane` on behalf of `tenant`, through
    /// the lane's fair front-end when one is enabled
    /// ([`Dispatcher::enable_fair_tenants`]): admission is bounded by
    /// the *tenant's own quota* (another tenant's backlog can never
    /// shed this request), and queued requests reach the dispatch queue
    /// in smooth weighted-round-robin order. Without a front-end this
    /// degenerates to [`Dispatcher::submit_lane`] (the tenant id is
    /// ignored).
    pub fn submit_lane_tenant(
        &mut self,
        lane: usize,
        tenant: usize,
        rq: QueuedRequest,
    ) -> Admission {
        self.submit_lane_tenant_deadline(lane, tenant, rq, f64::INFINITY)
    }

    /// [`Dispatcher::submit_lane_tenant`] with an absolute deadline tag:
    /// an EDF front-end ([`Dispatcher::enable_fair_tenants_spec`]) pops
    /// the earliest deadline within the tenant's share; FIFO front-ends
    /// ignore the tag (the `INFINITY` sentinel used by the plain path
    /// also sorts behind every real deadline, so mixing is safe).
    pub fn submit_lane_tenant_deadline(
        &mut self,
        lane: usize,
        tenant: usize,
        mut rq: QueuedRequest,
        deadline_s: f64,
    ) -> Admission {
        rq.bucket = self.policy.bucket_of(rq.m_est);
        rq.hedge = None;
        let l = &mut self.lanes[lane];
        let admission = match l.fair.as_mut() {
            None => l.offer(rq),
            Some(fair) => {
                let admission = fair.offer_deadline(tenant, rq, deadline_s);
                if admission.is_admitted() {
                    // The capacity view must include front-end backlog:
                    // account here, not at pass-through (pumping is
                    // accounting-neutral).
                    l.tracker.on_admit(rq.est_service_s);
                    l.pump_fair();
                }
                admission
            }
        };
        self.record_admission(&rq, lane, admission.is_admitted());
        admission
    }

    /// Queued requests still waiting in lane `lane`'s fair front-end
    /// (0 when the front-end is disabled).
    pub fn fair_depth_lane(&self, lane: usize) -> usize {
        self.lanes[lane].fair.as_ref().map_or(0, |f| f.depth())
    }

    /// Admission counters of `tenant`'s sub-queue on lane `lane`.
    /// Panics when the fair front-end is not enabled.
    pub fn fair_stats_lane(&self, lane: usize, tenant: usize) -> QueueStats {
        self.lanes[lane]
            .fair
            .as_ref()
            .expect("fair front-end not enabled")
            .stats_of(tenant)
    }

    /// Hedged submission on the classic pair: race lane 0 (edge) against
    /// lane 1 (cloud). See [`submit_hedged_lanes`].
    ///
    /// [`submit_hedged_lanes`]: Dispatcher::submit_hedged_lanes
    pub fn submit_hedged(
        &mut self,
        rq: QueuedRequest,
        edge_est_s: f64,
        cloud_est_s: f64,
    ) -> HedgeOutcome {
        match self.submit_hedged_lanes(rq, 0, edge_est_s, 1, cloud_est_s) {
            LaneHedgeOutcome::Hedged => HedgeOutcome::Hedged,
            LaneHedgeOutcome::Single(lane) => HedgeOutcome::Single(self.lanes[lane].kind),
            LaneHedgeOutcome::Rejected => HedgeOutcome::Rejected,
        }
    }

    /// Hedged submission across an arbitrary lane pair: enqueue a copy
    /// of `rq` on lane `lane_a` and lane `lane_b`, with per-lane service
    /// estimates (the copies differ only in `est_service_s`). First copy
    /// to finish wins; the loser is cancelled ([`CompletionKind`]). If
    /// only one lane admits, the request degrades to a normal submission
    /// there; if neither does, it is shed. O(1).
    ///
    /// In a fleet this races the best edge placement against the best
    /// cloud placement ([`crate::fleet::select`]); the lanes must be
    /// distinct.
    pub fn submit_hedged_lanes(
        &mut self,
        mut rq: QueuedRequest,
        lane_a: usize,
        est_a_s: f64,
        lane_b: usize,
        est_b_s: f64,
    ) -> LaneHedgeOutcome {
        assert!(lane_a != lane_b, "a hedge race needs two distinct lanes");
        rq.bucket = self.policy.bucket_of(rq.m_est);
        rq.hedge = None;
        // Room is checked up front so the race entry is allocated only
        // when both copies are expected to be admitted (`offer` applies
        // the same live-depth predicate today).
        if self.lanes[lane_a].has_room() && self.lanes[lane_b].has_room() {
            let key = self.hedges.insert(HedgeEntry {
                lanes: [lane_a, lane_b],
                est: [est_a_s, est_b_s],
                state: [CopyState::Queued, CopyState::Queued],
                winner: None,
            });
            rq.hedge = Some(key);
            let mut a_rq = rq;
            a_rq.est_service_s = est_a_s;
            let mut b_rq = rq;
            b_rq.est_service_s = est_b_s;
            let a_ok = self.lanes[lane_a].offer(a_rq).is_admitted();
            let b_ok = self.lanes[lane_b].offer(b_rq).is_admitted();
            if a_ok && b_ok {
                self.hedge_stats.hedged += 1;
                if let Some(rec) = self.recorder.as_mut() {
                    let admit = |lane: usize| ObsEvent::Admit {
                        id: rq.id,
                        lane: lane as u32,
                        hedged: true,
                    };
                    rec.record(rq.arrival_s, admit(lane_a));
                    rec.record(rq.arrival_s, admit(lane_b));
                }
                return LaneHedgeOutcome::Hedged;
            }
            // Defensive unwind: unreachable today, but if `offer` ever
            // grows a shed condition `has_room` doesn't know about, the
            // race must not half-exist. Freeing the entry makes any
            // admitted copy's key stale, and a stale key is inert — the
            // generation check classifies its completion as Solo and it
            // can never be mistaken for a ghost.
            self.hedges.remove(key);
            let outcome = match (a_ok, b_ok) {
                (true, false) => LaneHedgeOutcome::Single(lane_a),
                (false, true) => LaneHedgeOutcome::Single(lane_b),
                _ => LaneHedgeOutcome::Rejected,
            };
            self.record_hedge_degraded(&rq, outcome);
            return outcome;
        }
        // Degraded path: offer both copies anyway (the full lane counts
        // the rejection, exactly as a solo offer would).
        let mut a_rq = rq;
        a_rq.est_service_s = est_a_s;
        let mut b_rq = rq;
        b_rq.est_service_s = est_b_s;
        let a_ok = self.lanes[lane_a].offer(a_rq).is_admitted();
        let b_ok = self.lanes[lane_b].offer(b_rq).is_admitted();
        let outcome = match (a_ok, b_ok) {
            (true, false) => LaneHedgeOutcome::Single(lane_a),
            (false, true) => LaneHedgeOutcome::Single(lane_b),
            (false, false) => LaneHedgeOutcome::Rejected,
            // `offer` rejects whenever `has_room` is false (it is the
            // same predicate), so both lanes admitting after at least
            // one reported no room is an internal-invariant breach —
            // two unkeyed copies of one request would double-count.
            // Fail loudly rather than corrupt the accounting.
            (true, true) => unreachable!("offer admitted where has_room denied"),
        };
        self.record_hedge_degraded(&rq, outcome);
        outcome
    }

    /// Log a hedged submission that degraded to a solo admission or a
    /// shed (the race never formed, so the request's fate is solo).
    #[inline]
    fn record_hedge_degraded(&mut self, rq: &QueuedRequest, outcome: LaneHedgeOutcome) {
        match outcome {
            LaneHedgeOutcome::Single(lane) => self.record_admission(rq, lane, true),
            LaneHedgeOutcome::Rejected => self.record_admission(rq, 0, false),
            LaneHedgeOutcome::Hedged => {}
        }
    }

    /// Queue depth on `device` (pair surface; includes not-yet-purged
    /// cancelled twins).
    pub fn depth(&self, device: DeviceKind) -> usize {
        self.depth_lane(lane_idx(device))
    }

    /// Queue depth on lane `lane` (includes not-yet-purged cancelled
    /// twins).
    pub fn depth_lane(&self, lane: usize) -> usize {
        self.lanes[lane].queue.depth()
    }

    /// Live queue depth on lane `lane` (cancelled hedge ghosts
    /// excluded) — the telemetry queue-depth gauge.
    pub fn live_depth_lane(&self, lane: usize) -> usize {
        self.lanes[lane].queue.live_depth()
    }

    /// Workers on lane `lane` still executing a batch at `now_s` — the
    /// telemetry in-flight gauge.
    pub fn busy_workers_lane(&self, lane: usize, now_s: f64) -> usize {
        self.lanes[lane].tracker.busy_workers(now_s)
    }

    /// Admission counters for `device`'s queue (pair surface). Hedged
    /// submissions offer one copy per lane, so `offered` counts copies,
    /// not requests.
    pub fn queue_stats(&self, device: DeviceKind) -> QueueStats {
        self.queue_stats_lane(lane_idx(device))
    }

    /// Admission counters for lane `lane`'s queue.
    pub fn queue_stats_lane(&self, lane: usize) -> QueueStats {
        self.lanes[lane].queue.stats()
    }

    /// Micro-batch size accounting across all lanes.
    pub fn batch_stats(&self) -> BatchStats {
        self.stats
    }

    /// Hedged-dispatch outcome counters.
    pub fn hedge_stats(&self) -> HedgeStats {
        self.hedge_stats
    }

    /// Hedge races whose bookkeeping is still open (both copies pending,
    /// a loser still running, or a cancelled ghost awaiting purge).
    /// Zero once the dispatcher is drained — the arena leaks nothing.
    pub fn hedges_in_flight(&self) -> usize {
        self.hedges.len()
    }

    /// No queued work (dispatch queues and fair front-ends alike) and
    /// no in-flight batches?
    pub fn idle(&self) -> bool {
        self.lanes
            .iter()
            .all(|l| l.queue.is_empty() && l.fair.as_ref().is_none_or(|f| f.is_empty()))
            && self.pending.is_empty()
    }

    /// Time of the next event (batch start or batch completion), if any
    /// work is queued or in flight. Purges cancelled entries at the
    /// queue heads as a side effect. External event loops (closed-loop
    /// clients) interleave their submissions with this clock.
    pub fn next_event_s(&mut self) -> Option<f64> {
        let next_start = self.next_batch_start().map(|(_l, s)| s);
        let next_done = self.pending.peek().map(|p| p.0.done_s);
        match (next_start, next_done) {
            (None, None) => None,
            (Some(s), None) => Some(s),
            (None, Some(t)) => Some(t),
            (Some(s), Some(t)) => Some(s.min(t)),
        }
    }

    /// Earliest batch start across all lanes (lowest lane index wins
    /// ties — the edge before the cloud in the pair).
    fn next_batch_start(&mut self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for li in 0..self.lanes.len() {
            if let Some(s) = self.lane_next_start(li) {
                best = match best {
                    Some((_bl, bs)) if bs <= s => best,
                    _ => Some((li, s)),
                };
            }
        }
        best
    }

    /// Start time of lane `li`'s next batch (max of head arrival and the
    /// earliest-free worker), pumping the fair front-end and purging
    /// cancelled heads on the way.
    fn lane_next_start(&mut self, li: usize) -> Option<f64> {
        let lane = &mut self.lanes[li];
        if lane.down {
            // A crashed device dispatches nothing (its queue was wiped
            // at failure and offers refuse while down, so this is
            // belt-and-braces for the window between fail and drain).
            return None;
        }
        lane.pump_fair();
        let hedges = &mut self.hedges;
        loop {
            let head = match lane.queue.peek() {
                None => return None,
                Some(h) => *h,
            };
            if is_ghost(hedges, &head, li) {
                lane.queue.pop();
                lane.queue.unmark_dead();
                // The race is fully resolved once its ghost is gone:
                // free the arena entry (slot recycled, key goes stale).
                hedges.remove(head.hedge.expect("ghost carries its key"));
                continue;
            }
            let (_worker, free_s) = lane.tracker.earliest_free();
            return Some(free_s.max(head.arrival_s));
        }
    }

    /// Process the single earliest event — a batch completion or a batch
    /// start, completions first on ties — if it happens at or before
    /// `horizon_s`. Returns whether an event was processed;
    /// `on_complete` fires once per finished copy, in nondecreasing
    /// finish-time order.
    pub fn step<E, F>(&mut self, horizon_s: f64, exec: &mut E, on_complete: &mut F) -> bool
    where
        E: LaneExecutor,
        F: FnMut(Completion),
    {
        let next_start = self.next_batch_start();
        let next_done = self.pending.peek().map(|p| p.0.done_s);
        let completion_first = match (next_start, next_done) {
            (None, None) => return false,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (Some((_l, s)), Some(t)) => t <= s,
        };
        if completion_first {
            let done_s = next_done.expect("peeked completion exists");
            if done_s > horizon_s {
                return false;
            }
            self.flush_one(on_complete);
        } else {
            let (lane, start_s) = next_start.expect("peeked start exists");
            if start_s > horizon_s {
                return false;
            }
            self.dispatch_at(lane, start_s, exec);
        }
        true
    }

    /// Process every event (on all lanes, in global simulated-time
    /// order) up to and including `horizon_s`; `on_complete` fires once
    /// per finished copy. Drive with `horizon_s = next arrival time`
    /// while feeding arrivals, then once with `f64::INFINITY` to drain.
    pub fn run_until<E, F>(&mut self, horizon_s: f64, exec: &mut E, on_complete: &mut F)
    where
        E: LaneExecutor,
        F: FnMut(Completion),
    {
        while self.step(horizon_s, exec, on_complete) {}
    }

    /// Form + execute one batch on lane `li` at `start_s`, pushing its
    /// members onto the pending-completion heap. Allocation-free once
    /// warmed: the batch forms into the reused scratch buffer and ghost
    /// purges recycle their arena slots.
    fn dispatch_at<E>(&mut self, li: usize, start_s: f64, exec: &mut E)
    where
        E: LaneExecutor,
    {
        let kind = self.lanes[li].kind;
        let mut batch = std::mem::take(&mut self.scratch);
        {
            let lane = &mut self.lanes[li];
            let hedges = &mut self.hedges;
            self.policy
                .form_batch_into(&mut lane.queue, start_s, &mut batch, |rq| {
                    if is_ghost(hedges, rq, li) {
                        hedges.remove(rq.hedge.expect("ghost carries its key"));
                        true
                    } else {
                        false
                    }
                });
        }
        if batch.is_empty() {
            self.scratch = batch;
            return;
        }
        if let Some(armed) = self.armed.as_mut() {
            // A dispatched request is no longer stuck in a queue: its
            // deadline timer (which covers queue wait only) is
            // disarmed. The heap entry goes stale and is discarded when
            // it pops.
            for rq in &batch {
                if let Some(&(_seq, lane)) = armed.get(&rq.id) {
                    if lane == li {
                        armed.remove(&rq.id);
                    }
                }
            }
        }
        // Hedged members are now executing: too late to cancel them.
        for rq in &batch {
            if let Some(key) = rq.hedge {
                if let Some(entry) = self.hedges.get_mut(key) {
                    let side = entry.side_of(li);
                    entry.state[side] = CopyState::Running;
                }
            }
        }
        let est_sum: f64 = batch.iter().map(|r| r.est_service_s).sum();
        let service_s = exec.execute_lane(li, kind, &batch, start_s).max(0.0);
        let done_s = start_s + service_s;
        {
            let lane = &mut self.lanes[li];
            let (worker, _free) = lane.tracker.earliest_free();
            lane.tracker.on_dispatch(worker, est_sum, done_s);
            // Feeds the opt-in amortisation fit; a no-op unless
            // `enable_batch_aware_wait` armed this lane's tracker.
            lane.tracker.observe_batch(batch.len(), est_sum, service_s);
        }
        self.stats.record(batch.len());
        let batch_size = batch.len();
        if let Some(rec) = self.recorder.as_mut() {
            rec.record(
                start_s,
                ObsEvent::BatchFormed { lane: li as u32, size: batch_size as u32, start_s },
            );
            rec.record(
                start_s,
                ObsEvent::DispatchStart { lane: li as u32, size: batch_size as u32, done_s },
            );
        }
        for request in batch.drain(..) {
            let seq = self.seq;
            self.seq += 1;
            self.pending.push(Reverse(Pending {
                done_s,
                seq,
                start_s,
                batch_size,
                lane: li,
                request,
            }));
        }
        self.scratch = batch;
    }

    /// Fire the earliest pending completion event.
    fn flush_one<F>(&mut self, on_complete: &mut F)
    where
        F: FnMut(Completion),
    {
        let Reverse(p) = self.pending.pop().expect("pending completion exists");
        let kind = self.resolve_completion(
            p.lane,
            p.request.hedge,
            p.request.id,
            p.request.payload,
            p.done_s,
        );
        if let Some(rec) = self.recorder.as_mut() {
            rec.record(
                p.done_s,
                ObsEvent::Complete { id: p.request.id, lane: p.lane as u32, kind },
            );
        }
        if let Some(det) = self.detector.as_mut() {
            det.observe_exec(
                p.lane as u32,
                p.done_s,
                p.done_s - p.start_s,
                p.request.est_service_s,
            );
            self.drain_alerts(p.done_s);
        }
        on_complete(Completion {
            request: p.request,
            device: self.lanes[p.lane].kind,
            lane: p.lane,
            start_s: p.start_s,
            done_s: p.done_s,
            batch_size: p.batch_size,
            kind,
        });
    }

    /// Classify one finished copy and update the hedge bookkeeping:
    /// first finisher wins and cancels its twin (reclaiming queued
    /// capacity); a later finisher is wasted work. All O(1) — one
    /// generation-checked arena access, no hashing.
    fn resolve_completion(
        &mut self,
        lane: usize,
        hedge: Option<SlabKey>,
        id: u64,
        payload: usize,
        done_s: f64,
    ) -> CompletionKind {
        let key = match hedge {
            None => return CompletionKind::Solo,
            Some(k) => k,
        };
        let (kind, cancel, twin_destroyed) = match self.hedges.get_mut(key) {
            // Unreachable in practice (a dispatched copy's race entry
            // outlives it); treat a stale key as a solo completion.
            None => return CompletionKind::Solo,
            Some(entry) => {
                let side = entry.side_of(lane);
                entry.state[side] = CopyState::Done;
                if entry.winner.is_some() {
                    (CompletionKind::HedgeLoss, None, false)
                } else {
                    entry.winner = Some(side as u8);
                    let twin = 1 - side;
                    match entry.state[twin] {
                        CopyState::Queued => {
                            // Twin still queued: mark it cancelled in
                            // the race entry itself. The ghost is purged
                            // lazily (queue head / batcher lookahead),
                            // which also frees this entry.
                            entry.state[twin] = CopyState::Cancelled;
                            (
                                CompletionKind::HedgeWin,
                                Some((entry.lanes[twin], entry.est[twin])),
                                false,
                            )
                        }
                        // The twin copy was physically destroyed by a
                        // lane failure ([`Dispatcher::fail_lane`]) —
                        // never a normal cancel, those only happen here
                        // at win time. The race is closed and no lazy
                        // ghost purge will ever find the twin, so the
                        // entry is released below.
                        CopyState::Cancelled => (CompletionKind::HedgeWin, None, true),
                        // Twin running: keep the entry so its
                        // completion is classified as a loss.
                        _ => (CompletionKind::HedgeWin, None, false),
                    }
                }
            }
        };
        if twin_destroyed {
            self.hedges.remove(key);
        }
        match kind {
            CompletionKind::HedgeLoss => {
                // Twin already won; the race is fully resolved.
                self.hedges.remove(key);
                self.hedge_stats.losers_run += 1;
            }
            CompletionKind::HedgeWin => {
                match self.lanes[lane].kind {
                    DeviceKind::Edge => self.hedge_stats.wins_edge += 1,
                    DeviceKind::Cloud => self.hedge_stats.wins_cloud += 1,
                }
                if let Some((twin_lane, est)) = cancel {
                    // Reclaim the cancelled twin's backlog share and
                    // admission slot now; the entry itself stays until
                    // the ghost is physically purged.
                    self.hedge_stats.cancelled_unrun += 1;
                    if self.track_cancelled {
                        self.cancelled_payloads.push(payload);
                    }
                    {
                        let lane = &mut self.lanes[twin_lane];
                        lane.tracker.on_cancel(est);
                        lane.queue.mark_dead();
                    }
                    if let Some(rec) = self.recorder.as_mut() {
                        rec.record(
                            done_s,
                            ObsEvent::HedgeCancel { id, lane: twin_lane as u32 },
                        );
                    }
                }
            }
            CompletionKind::Solo => {}
        }
        kind
    }

    // ------------------------------------------- failure injection & timers

    /// Enable per-request queue-wait deadline timers
    /// ([`Dispatcher::arm_timeout`]). Off by default: without this call
    /// the dispatcher carries no timer state at all, so every legacy
    /// harness behaves — and reports — identically. Idempotent.
    pub fn enable_timers(&mut self) {
        if self.armed.is_none() {
            self.armed = Some(std::collections::HashMap::new());
        }
    }

    /// Arm (or re-arm) a queue-wait deadline timer for the solo request
    /// `id` just admitted on `lane`: if it is still queued there when
    /// `deadline_s` arrives, [`Dispatcher::fire_timeouts`] pulls it out
    /// for the caller to requeue elsewhere. Re-arming supersedes any
    /// previous timer for the same id (the old heap entry goes stale).
    /// Panics unless [`Dispatcher::enable_timers`] was called.
    pub fn arm_timeout(&mut self, id: u64, lane: usize, deadline_s: f64) {
        self.timer_seq += 1;
        let seq = self.timer_seq;
        self.armed
            .as_mut()
            .expect("arm_timeout requires enable_timers")
            .insert(id, (seq, lane));
        self.timers.push(Reverse(TimerEntry { deadline_s, seq, id, lane }));
    }

    /// Earliest timer deadline, stale entries included (they pop as
    /// no-ops in [`Dispatcher::fire_timeouts`] — lazy disarm, like the
    /// hedge ghost purge). `None` when no timers are armed.
    pub fn next_timeout_s(&self) -> Option<f64> {
        self.timers.peek().map(|t| t.0.deadline_s)
    }

    /// Pop every timer due at or before `now_s`. Each one whose request
    /// is genuinely still queued on its armed lane is pulled from the
    /// queue (its backlog share reclaimed, a
    /// [`TimeoutFired`](ObsEvent::TimeoutFired) event recorded) and
    /// appended to `fired` for the caller to requeue with backoff;
    /// stale entries — the request dispatched or was re-armed — are
    /// discarded silently.
    pub fn fire_timeouts(&mut self, now_s: f64, fired: &mut Vec<QueuedRequest>) {
        loop {
            let head = match self.timers.peek() {
                Some(&Reverse(t)) if t.deadline_s <= now_s => t,
                _ => break,
            };
            self.timers.pop();
            let live = matches!(
                self.armed.as_ref().and_then(|a| a.get(&head.id)),
                Some(&(seq, lane)) if seq == head.seq && lane == head.lane
            );
            if !live {
                continue; // stale: dispatched or re-armed elsewhere
            }
            if let Some(armed) = self.armed.as_mut() {
                armed.remove(&head.id);
            }
            let mut pulled = None;
            {
                let lane = &mut self.lanes[head.lane];
                for i in 0..lane.queue.depth() {
                    let rq = *lane.queue.get(i).expect("index below queue depth");
                    if rq.id == head.id && rq.hedge.is_none() {
                        lane.queue.remove(i);
                        lane.tracker.on_cancel(rq.est_service_s);
                        pulled = Some(rq);
                        break;
                    }
                }
            }
            if let Some(rq) = pulled {
                self.record(
                    now_s,
                    ObsEvent::TimeoutFired { id: head.id, lane: head.lane as u32 },
                );
                fired.push(rq);
            }
        }
    }

    /// Crash lane `li` at `now_s`: its queue and in-flight batches are
    /// lost (device memory is gone) and admissions refuse until
    /// [`Dispatcher::recover_lane`]. Requests whose only live copy died
    /// are appended to `killed` in deterministic order — queued copies
    /// in FIFO order first, then in-flight copies in dispatch order —
    /// for the caller to re-route; hedged copies whose twin is still
    /// alive are *not* killed (the twin carries the request on).
    /// Records a [`DeviceDown`](ObsEvent::DeviceDown) event and returns
    /// the number of in-flight copies destroyed.
    pub fn fail_lane(
        &mut self,
        li: usize,
        now_s: f64,
        killed: &mut Vec<QueuedRequest>,
    ) -> usize {
        self.lanes[li].down = true;
        // Queued copies die first, in FIFO order (the wipe also resets
        // the queue's dead-ghost count: ghosts are resolved here, not
        // lazily).
        let mut wiped = Vec::new();
        self.lanes[li].queue.wipe_into(&mut wiped);
        for rq in wiped {
            self.kill_copy(li, rq, killed);
        }
        // Then in-flight copies, in dispatch order: drain the pending
        // heap, keep the survivors, sort the dead by dispatch seq.
        let mut survivors = Vec::with_capacity(self.pending.len());
        let mut dead = Vec::new();
        for Reverse(p) in std::mem::take(&mut self.pending).into_vec() {
            if p.lane == li {
                dead.push(p);
            } else {
                survivors.push(Reverse(p));
            }
        }
        self.pending = BinaryHeap::from(survivors);
        dead.sort_by_key(|p| p.seq);
        let n_inflight = dead.len();
        for p in &dead {
            self.kill_copy(li, p.request, killed);
        }
        self.lanes[li].tracker.reset_at(now_s);
        self.record(now_s, ObsEvent::DeviceDown { lane: li as u32 });
        n_inflight
    }

    /// Bring a crashed lane back at `now_s`: empty queue, idle workers
    /// (busy-until times are clamped forward so the device never owes
    /// phantom work from before the outage). Records a
    /// [`DeviceUp`](ObsEvent::DeviceUp) event.
    pub fn recover_lane(&mut self, li: usize, now_s: f64) {
        {
            let lane = &mut self.lanes[li];
            lane.down = false;
            lane.tracker.advance_to(now_s);
        }
        self.record(now_s, ObsEvent::DeviceUp { lane: li as u32 });
    }

    /// Is lane `lane` currently crashed ([`Dispatcher::fail_lane`])?
    pub fn lane_down(&self, lane: usize) -> bool {
        self.lanes[lane].down
    }

    /// Classify one copy destroyed by [`Dispatcher::fail_lane`] on lane
    /// `li`. A solo copy is the request's only incarnation: disarm its
    /// timer and report it killed. A hedged copy depends on the race
    /// state — a cancelled ghost or a decided race's straggler just
    /// closes the arena entry; a copy whose twin already died in an
    /// earlier failure is the end of its request; a copy whose twin is
    /// still alive hands the request over to the twin.
    fn kill_copy(&mut self, li: usize, rq: QueuedRequest, killed: &mut Vec<QueuedRequest>) {
        let Some(key) = rq.hedge else {
            if let Some(armed) = self.armed.as_mut() {
                armed.remove(&rq.id);
            }
            killed.push(rq);
            return;
        };
        let entry = match self.hedges.get(key) {
            Some(e) => *e,
            // Stale key (defensive — a live copy's entry outlives it).
            None => return,
        };
        let side = entry.side_of(li);
        if entry.state[side] == CopyState::Cancelled {
            // Ghost awaiting lazy purge: its result was already
            // delivered by the twin.
            self.hedges.remove(key);
            return;
        }
        if entry.winner.is_some() {
            // Straggling loser of a decided race: close the entry.
            self.hedges.remove(key);
            return;
        }
        if entry.state[1 - side] == CopyState::Cancelled {
            // The twin was destroyed by an earlier lane failure: this
            // copy was the request's last incarnation.
            self.hedges.remove(key);
            killed.push(rq);
            return;
        }
        // Twin still queued or running: it carries the request on.
        if let Some(e) = self.hedges.get_mut(key) {
            e.state[side] = CopyState::Cancelled;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fixed per-request time, batch = max + residual·rest.
    struct FixedExec {
        per_request_s: f64,
        residual: f64,
    }

    impl BatchExecutor for FixedExec {
        fn execute(&mut self, _d: DeviceKind, batch: &[QueuedRequest], _s: f64) -> f64 {
            let each = self.per_request_s;
            each + self.residual * each * (batch.len() - 1) as f64
        }
    }

    /// Per-device fixed batch time.
    struct AsymExec {
        edge_s: f64,
        cloud_s: f64,
    }

    impl BatchExecutor for AsymExec {
        fn execute(&mut self, d: DeviceKind, _batch: &[QueuedRequest], _s: f64) -> f64 {
            match d {
                DeviceKind::Edge => self.edge_s,
                DeviceKind::Cloud => self.cloud_s,
            }
        }
    }

    /// Per-lane fixed batch time (the fleet executor shape).
    struct PerLaneExec {
        lane_s: Vec<f64>,
    }

    impl LaneExecutor for PerLaneExec {
        fn execute_lane(
            &mut self,
            lane: usize,
            _d: DeviceKind,
            _batch: &[QueuedRequest],
            _s: f64,
        ) -> f64 {
            self.lane_s[lane]
        }
    }

    fn rq(id: u64, arrival_s: f64, m_est: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            payload: id as usize,
            n: 10,
            m_est,
            est_service_s: 0.1,
            arrival_s,
            bucket: 0, // overwritten by submit()
            hedge: None,
        }
    }

    fn collect_completions<E: LaneExecutor>(
        disp: &mut Dispatcher,
        exec: &mut E,
        horizon_s: f64,
    ) -> Vec<Completion> {
        let mut out = Vec::new();
        disp.run_until(horizon_s, exec, &mut |c| out.push(c));
        out
    }

    /// A 1-edge × 3-cloud fleet (4 lanes) used by the fleet-shape tests.
    fn fleet4() -> Dispatcher {
        let spec = |kind, workers| LaneSpec { kind, workers, max_queue_depth: 512 };
        Dispatcher::with_lanes(
            &[
                spec(DeviceKind::Edge, 1),
                spec(DeviceKind::Cloud, 1),
                spec(DeviceKind::Cloud, 1),
                spec(DeviceKind::Cloud, 1),
            ],
            BatchPolicy::default(),
        )
    }

    #[test]
    fn lone_request_runs_immediately_without_batching_delay() {
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.2 };
        assert!(disp.submit(DeviceKind::Edge, rq(0, 1.0, 10.0)).is_admitted());
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 1);
        assert!((done[0].start_s - 1.0).abs() < 1e-12);
        assert!((done[0].done_s - 1.1).abs() < 1e-12);
        assert_eq!(done[0].batch_size, 1);
        assert_eq!(done[0].kind, CompletionKind::Solo);
        assert_eq!(done[0].lane, 0, "pair edge is lane 0");
        assert!(disp.idle());
    }

    #[test]
    fn backlog_batches_and_amortises() {
        // One edge worker, four same-bucket requests arriving together:
        // they ride one batch and finish far sooner than serially.
        let cfg = DispatcherConfig { edge_workers: 1, ..Default::default() };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.2 };
        for i in 0..4 {
            disp.submit(DeviceKind::Edge, rq(i, 0.0, 10.0));
        }
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 4);
        assert_eq!(done[0].batch_size, 4);
        // 0.1 + 3·0.02 = 0.16 ≪ 0.4 serial.
        assert!((done[0].done_s - 0.16).abs() < 1e-9);
        assert!((disp.batch_stats().mean_batch_size() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn completions_fire_at_finish_time_not_dispatch_time() {
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.0 };
        disp.submit(DeviceKind::Cloud, rq(0, 5.0, 10.0));
        assert!(collect_completions(&mut disp, &mut exec, 4.9).is_empty());
        // At horizon 5.0 the batch starts (worker busy) but its finish
        // event at 5.1 has not fired yet.
        assert!(collect_completions(&mut disp, &mut exec, 5.0).is_empty());
        assert!(disp.expected_wait_s(DeviceKind::Cloud, 5.0) > 0.0);
        let done = collect_completions(&mut disp, &mut exec, 5.1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].device, DeviceKind::Cloud);
        assert_eq!(done[0].lane, 1, "pair cloud is lane 1");
        assert!(disp.idle());
    }

    #[test]
    fn dispatch_order_is_global_start_time() {
        // The cloud head arrives before the edge head: cloud dispatches
        // (and completes) first even though edge is lane 0.
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.01, residual: 0.0 };
        disp.submit(DeviceKind::Edge, rq(0, 2.0, 10.0));
        disp.submit(DeviceKind::Cloud, rq(1, 1.0, 10.0));
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].device, DeviceKind::Cloud);
        assert_eq!(done[1].device, DeviceKind::Edge);
        assert!(done[0].done_s <= done[1].done_s);
    }

    #[test]
    fn expected_wait_rises_with_backlog_and_falls_with_workers() {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 4,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        for i in 0..8 {
            disp.submit(DeviceKind::Edge, rq(i, 0.0, 10.0));
            disp.submit(DeviceKind::Cloud, rq(100 + i, 0.0, 10.0));
        }
        let we = disp.expected_wait_s(DeviceKind::Edge, 0.0);
        let wc = disp.expected_wait_s(DeviceKind::Cloud, 0.0);
        assert!((we - 0.8).abs() < 1e-12, "edge wait {we}");
        assert!((wc - 0.2).abs() < 1e-12, "cloud wait {wc}");
    }

    #[test]
    fn conservation_admitted_equals_completed() {
        let cfg = DispatcherConfig {
            max_queue_depth: 16,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = FixedExec { per_request_s: 0.05, residual: 0.1 };
        let mut completed = 0usize;
        let mut rejected = 0usize;
        for i in 0..200u64 {
            let t = i as f64 * 0.01;
            disp.run_until(t, &mut exec, &mut |_c| completed += 1);
            let dev = if i % 3 == 0 { DeviceKind::Edge } else { DeviceKind::Cloud };
            if !disp.submit(dev, rq(i, t, (i % 40) as f64)).is_admitted() {
                rejected += 1;
            }
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut |_c| completed += 1);
        assert_eq!(completed + rejected, 200);
        let qs_e = disp.queue_stats(DeviceKind::Edge);
        let qs_c = disp.queue_stats(DeviceKind::Cloud);
        assert_eq!(qs_e.offered + qs_c.offered, 200);
        assert_eq!(qs_e.rejected + qs_c.rejected, rejected as u64);
        assert!(disp.idle());
    }

    #[test]
    fn hedge_winner_cancels_queued_twin() {
        // Cloud is busy behind a long job, so the hedged cloud copy is
        // still queued when the edge copy finishes: it must be purged
        // without running and its backlog share reclaimed.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.1, cloud_s: 5.0 };
        disp.submit(DeviceKind::Cloud, rq(0, 0.0, 10.0)); // 5 s blocker
        assert_eq!(
            disp.submit_hedged(rq(1, 0.1, 10.0), 0.1, 0.1),
            HedgeOutcome::Hedged
        );
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        // Blocker + edge win; the cloud twin never executes.
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].kind, CompletionKind::HedgeWin);
        assert_eq!(done[0].device, DeviceKind::Edge);
        assert_eq!(done[0].request.id, 1);
        assert_eq!(done[1].kind, CompletionKind::Solo);
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 1);
        assert_eq!(hs.wins_edge, 1);
        assert_eq!(hs.cancelled_unrun, 1);
        assert_eq!(hs.losers_run, 0);
        assert!(disp.idle());
        assert_eq!(disp.hedges_in_flight(), 0, "drained arena must be empty");
        // Backlog fully reclaimed once drained.
        assert_eq!(disp.expected_wait_s(DeviceKind::Cloud, 100.0), 0.0);
    }

    #[test]
    fn hedge_winner_is_first_finisher_not_first_dispatched() {
        // Both lanes idle: both copies start at t=0 (edge dispatched
        // first), but the cloud copy finishes sooner — it must win, and
        // the already-running edge copy completes as wasted work.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.5, cloud_s: 0.1 };
        assert_eq!(
            disp.submit_hedged(rq(0, 0.0, 10.0), 0.5, 0.1),
            HedgeOutcome::Hedged
        );
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].kind, CompletionKind::HedgeWin);
        assert_eq!(done[0].device, DeviceKind::Cloud);
        assert_eq!(done[1].kind, CompletionKind::HedgeLoss);
        assert_eq!(done[1].device, DeviceKind::Edge);
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 1);
        assert_eq!(hs.wins_cloud, 1);
        assert_eq!(hs.losers_run, 1);
        assert_eq!(hs.cancelled_unrun, 0);
        assert_eq!(disp.hedges_in_flight(), 0);
    }

    #[test]
    fn queued_twin_that_starts_before_winner_finishes_still_races() {
        // Edge copy starts at 0 and takes 5 s; the cloud twin is queued
        // behind a 1 s blocker, starts at 1.0 — *before* the edge copy
        // finishes — so it must not be cancelled, and it wins at 2.0.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 5.0, cloud_s: 1.0 };
        // Different bucket (m_est 30 vs 10) so the twin cannot ride the
        // blocker's batch: it genuinely waits, then starts at 1.0.
        disp.submit(DeviceKind::Cloud, rq(0, 0.0, 30.0)); // blocker, done 1.0
        assert_eq!(
            disp.submit_hedged(rq(1, 0.0, 10.0), 5.0, 1.0),
            HedgeOutcome::Hedged
        );
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        let kinds: Vec<(u64, CompletionKind)> =
            done.iter().map(|c| (c.request.id, c.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                (0, CompletionKind::Solo),      // blocker finishes at 1.0
                (1, CompletionKind::HedgeWin),  // cloud twin finishes at 2.0
                (1, CompletionKind::HedgeLoss), // edge copy finishes at 5.0
            ]
        );
        let hs = disp.hedge_stats();
        assert_eq!(hs.wins_cloud, 1);
        assert_eq!(hs.losers_run, 1);
        assert_eq!(hs.cancelled_unrun, 0);
        assert_eq!(disp.hedges_in_flight(), 0);
    }

    #[test]
    fn cancelled_twin_frees_its_admission_slot() {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            max_queue_depth: 3,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.1, cloud_s: 10.0 };
        disp.submit(DeviceKind::Cloud, rq(0, 0.0, 30.0)); // blocker, 10 s
        disp.submit(DeviceKind::Cloud, rq(1, 0.0, 20.0)); // queued solo
        assert_eq!(
            disp.submit_hedged(rq(2, 0.0, 10.0), 0.1, 0.1),
            HedgeOutcome::Hedged
        );
        let mut comps = Vec::new();
        disp.run_until(0.5, &mut exec, &mut |c| comps.push(c));
        // Edge copy won at 0.1; the cloud twin sits mid-queue as a
        // cancelled ghost: physically present, but its admission slot
        // is released.
        assert_eq!(disp.hedge_stats().cancelled_unrun, 1);
        assert_eq!(disp.depth(DeviceKind::Cloud), 2);
        assert!(disp.submit(DeviceKind::Cloud, rq(3, 0.6, 20.0)).is_admitted());
        assert!(disp.submit(DeviceKind::Cloud, rq(4, 0.7, 20.0)).is_admitted());
        // Three live entries now: the bound holds again.
        assert!(!disp.submit(DeviceKind::Cloud, rq(5, 0.8, 20.0)).is_admitted());
        disp.run_until(f64::INFINITY, &mut exec, &mut |c| comps.push(c));
        assert!(disp.idle());
        assert_eq!(disp.hedges_in_flight(), 0, "purged ghost must free its entry");
        let results = comps.iter().filter(|c| c.kind.is_result()).count();
        assert_eq!(results, 5, "4 solos + 1 hedge winner");
    }

    #[test]
    fn hedge_degrades_to_single_when_one_lane_is_full() {
        let cfg = DispatcherConfig { max_queue_depth: 1, ..Default::default() };
        let mut disp = Dispatcher::new(&cfg);
        disp.submit(DeviceKind::Edge, rq(0, 0.0, 10.0)); // fills edge
        match disp.submit_hedged(rq(1, 0.0, 10.0), 0.1, 0.1) {
            HedgeOutcome::Single(DeviceKind::Cloud) => {}
            o => panic!("expected Single(Cloud), got {o:?}"),
        }
        assert_eq!(disp.hedge_stats().hedged, 0);
        assert_eq!(disp.hedges_in_flight(), 0, "degraded hedge must not leak");
        // Both lanes full now: the next hedge is shed outright.
        assert_eq!(
            disp.submit_hedged(rq(2, 0.0, 10.0), 0.1, 0.1),
            HedgeOutcome::Rejected
        );
    }

    #[test]
    fn recycled_arena_slots_never_confuse_races() {
        // Run many sequential hedge races through a 1-entry-deep arena:
        // every race recycles the same physical slot, and the generation
        // check must keep each resolution tied to its own race.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.01, cloud_s: 0.5 };
        let mut wins = 0u64;
        for i in 0..50u64 {
            let t = i as f64;
            disp.run_until(t, &mut exec, &mut |c| {
                if c.kind == CompletionKind::HedgeWin {
                    wins += 1;
                }
            });
            assert_eq!(
                disp.submit_hedged(rq(i, t, 10.0), 0.01, 0.5),
                HedgeOutcome::Hedged
            );
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut |c| {
            if c.kind == CompletionKind::HedgeWin {
                wins += 1;
            }
        });
        assert_eq!(wins, 50, "every race has exactly one winner");
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 50);
        assert_eq!(hs.wins_edge + hs.wins_cloud, 50);
        assert_eq!(hs.cancelled_unrun + hs.losers_run, 50);
        assert_eq!(disp.hedges_in_flight(), 0);
        assert!(disp.idle());
    }

    // ------------------------------------------------------------ fleet lanes

    #[test]
    fn fleet_lanes_route_independently() {
        // 4 lanes, distinct service times: every lane runs its own
        // queue/tracker, completions carry the right lane id and tier.
        let mut disp = fleet4();
        let mut exec = PerLaneExec { lane_s: vec![0.4, 0.1, 0.2, 0.3] };
        for lane in 0..4 {
            assert!(disp.submit_lane(lane, rq(lane as u64, 0.0, 10.0)).is_admitted());
        }
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 4);
        // Finish order follows per-lane service times.
        let lanes: Vec<usize> = done.iter().map(|c| c.lane).collect();
        assert_eq!(lanes, vec![1, 2, 3, 0]);
        assert_eq!(done[3].device, DeviceKind::Edge);
        assert_eq!(done[0].device, DeviceKind::Cloud);
        assert!(disp.idle());
    }

    #[test]
    fn fleet_tie_break_prefers_lowest_lane_index() {
        // Equal start times on three idle lanes: dispatch order (hence
        // seq / completion order at equal finish times) must scan lanes
        // in index order — the N-lane generalisation of edge-wins-ties.
        let mut disp = fleet4();
        let mut exec = PerLaneExec { lane_s: vec![0.1, 0.1, 0.1, 0.1] };
        for lane in [3usize, 1, 0] {
            disp.submit_lane(lane, rq(lane as u64, 0.0, 10.0));
        }
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        let lanes: Vec<usize> = done.iter().map(|c| c.lane).collect();
        assert_eq!(lanes, vec![0, 1, 3], "lowest lane must dispatch first on ties");
    }

    #[test]
    fn fleet_hedge_races_arbitrary_lane_pair() {
        // Hedge across lanes (0, 3): the race entry records its lane
        // pair, so a win on lane 3 cancels the queued twin on lane 0.
        let mut disp = fleet4();
        // Lane 0 blocked for 5 s; lane 3 fast.
        let mut exec = PerLaneExec { lane_s: vec![5.0, 0.1, 0.1, 0.2] };
        disp.submit_lane(0, rq(0, 0.0, 30.0)); // blocker on the edge
        assert_eq!(
            disp.submit_hedged_lanes(rq(1, 0.0, 10.0), 0, 5.0, 3, 0.2),
            LaneHedgeOutcome::Hedged
        );
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        // Lane-3 copy wins at 0.2; the lane-0 twin (queued behind the
        // blocker) is purged unrun; the blocker completes solo.
        let resolved: Vec<(u64, usize, CompletionKind)> =
            done.iter().map(|c| (c.request.id, c.lane, c.kind)).collect();
        assert_eq!(
            resolved,
            vec![
                (1, 3, CompletionKind::HedgeWin),
                (0, 0, CompletionKind::Solo),
            ]
        );
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 1);
        assert_eq!(hs.wins_cloud, 1, "lane 3 is cloud tier");
        assert_eq!(hs.cancelled_unrun, 1);
        assert_eq!(disp.hedges_in_flight(), 0);
        assert!(disp.idle());
        assert_eq!(disp.expected_wait_lane(0, 100.0), 0.0, "twin backlog reclaimed");
    }

    #[test]
    fn fleet_conservation_across_many_lanes() {
        // Random-ish traffic over 4 lanes with hedges on rotating lane
        // pairs: results == admitted, the arena drains, nothing leaks.
        let mut disp = fleet4();
        let mut exec = PerLaneExec { lane_s: vec![0.03, 0.01, 0.02, 0.015] };
        let mut admitted = 0u64;
        let mut results = 0u64;
        let mut on_c = |c: Completion| {
            if c.kind.is_result() {
                results += 1;
            }
        };
        for i in 0..400u64 {
            let t = i as f64 * 0.005;
            disp.run_until(t, &mut exec, &mut on_c);
            let rq = rq(i, t, (i % 32) as f64);
            if i % 5 == 0 {
                let cloud = 1 + (i as usize / 5) % 3;
                match disp.submit_hedged_lanes(rq, 0, 0.03, cloud, 0.02) {
                    LaneHedgeOutcome::Hedged | LaneHedgeOutcome::Single(_) => admitted += 1,
                    LaneHedgeOutcome::Rejected => {}
                }
            } else if disp.submit_lane((i % 4) as usize, rq).is_admitted() {
                admitted += 1;
            }
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut on_c);
        assert_eq!(results, admitted);
        assert!(disp.idle());
        assert_eq!(disp.hedges_in_flight(), 0);
        for lane in 0..4 {
            assert_eq!(disp.depth_lane(lane), 0);
            assert!(disp.expected_wait_lane(lane, 1e9) < 1e-9);
        }
        let hs = disp.hedge_stats();
        assert_eq!(hs.wins_edge + hs.wins_cloud, hs.hedged);
        assert_eq!(hs.cancelled_unrun + hs.losers_run, hs.hedged);
    }

    // ------------------------------------------------------- fair front-end

    /// Drive a flood (tenant 0, far beyond capacity) plus a trickle
    /// (tenant 1) through the edge lane; returns (worst trickle
    /// latency, trickle shed count, flood shed count).
    fn flood_run(fair_tenants: usize) -> (f64, u64, u64) {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            fair_tenants,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        // Serial executor (residual 1.0): capacity is 100 r/s whatever
        // the batching, so a 1000 r/s flood drowns the lane.
        let mut exec = FixedExec { per_request_s: 0.01, residual: 1.0 };
        let mut worst_trickle = 0.0f64;
        let mut trickle_shed = 0u64;
        let mut flood_shed = 0u64;
        let mut on_c = |c: Completion| {
            if c.request.id >= 10_000 {
                let latency = c.done_s - c.request.arrival_s;
                if latency > worst_trickle {
                    worst_trickle = latency;
                }
            }
        };
        // 500 flood arrivals keep the peak backlog (~450) inside the
        // 512 shared bound, so the FIFO run sheds nothing and the
        // comparison is purely about *where* the trickle tenant waits.
        let mut trickle_i = 0u64;
        for i in 0..500u64 {
            let t = i as f64 * 0.001;
            disp.run_until(t, &mut exec, &mut on_c);
            // The flood: 1000 r/s of tenant-0 traffic.
            if !disp.submit_lane_tenant(0, 0, rq(i, t, 10.0)).is_admitted() {
                flood_shed += 1;
            }
            // The trickle: one tenant-1 request every 30 ms.
            if i % 30 == 15 {
                let trq = rq(10_000 + trickle_i, t, 10.0);
                trickle_i += 1;
                if !disp.submit_lane_tenant(0, 1, trq).is_admitted() {
                    trickle_shed += 1;
                }
            }
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut on_c);
        assert!(disp.idle());
        (worst_trickle, trickle_shed, flood_shed)
    }

    #[test]
    fn fair_front_end_protects_neighbour_tail_from_a_flood() {
        // THE multi-tenant acceptance test: a noisy tenant flooding 10x
        // capacity must no longer inflate a neighbour's tail. Shared
        // FIFO: the trickle tenant queues behind the whole flood
        // backlog (seconds of wait). Fair front-end: its requests pass
        // through its own quota and the WRR pump, bounded by the
        // pass-through window.
        let (fifo_worst, fifo_shed, _f0) = flood_run(0);
        let (fair_worst, fair_shed, fair_flood_shed) = flood_run(2);
        assert_eq!(fifo_shed, 0, "trickle shed under shared FIFO");
        assert_eq!(fair_shed, 0, "trickle shed under fair front-end");
        assert!(
            fifo_worst > 2.0,
            "flood never hurt the FIFO trickle tenant (worst {fifo_worst})"
        );
        assert!(
            fair_worst < 1.0,
            "fair front-end left the trickle tenant waiting {fair_worst}s"
        );
        assert!(
            fair_worst * 3.0 < fifo_worst,
            "fair front-end bought too little: {fair_worst} vs {fifo_worst}"
        );
        // The flooding tenant sheds its own overflow (quota), instead
        // of consuming the shared bound.
        assert!(fair_flood_shed > 0, "flood never shed under its quota");
    }

    #[test]
    fn fair_front_end_conserves_and_reports_stats() {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            max_queue_depth: 8,
            fair_tenants: 2,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = FixedExec { per_request_s: 0.01, residual: 1.0 };
        let mut results = 0u64;
        let mut admitted = 0u64;
        for i in 0..40u64 {
            let t = i as f64 * 0.002;
            disp.run_until(t, &mut exec, &mut |c| {
                if c.kind.is_result() {
                    results += 1;
                }
            });
            let tenant = (i % 2) as usize;
            let lane = (i % 2) as usize;
            if disp.submit_lane_tenant(lane, tenant, rq(i, t, 10.0)).is_admitted() {
                admitted += 1;
            }
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut |c| {
            if c.kind.is_result() {
                results += 1;
            }
        });
        assert_eq!(results, admitted, "fair-path conservation broken");
        assert!(disp.idle());
        assert_eq!(disp.fair_depth_lane(0), 0);
        assert_eq!(disp.fair_depth_lane(1), 0);
        // Quota = max_depth / tenants = 4 per tenant per lane.
        let s0 = disp.fair_stats_lane(0, 0);
        assert_eq!(s0.offered, s0.admitted + s0.rejected);
        // Without a front-end the tenant entry point degenerates to
        // submit_lane.
        let mut plain = Dispatcher::new(&DispatcherConfig::default());
        assert!(plain.submit_lane_tenant(0, 7, rq(0, 0.0, 10.0)).is_admitted());
        assert_eq!(plain.fair_depth_lane(0), 0);
    }

    #[test]
    #[should_panic]
    fn empty_lane_list_rejected_at_construction() {
        Dispatcher::with_lanes(&[], BatchPolicy::default());
    }

    #[test]
    #[should_panic]
    fn hedge_on_same_lane_rejected() {
        let mut disp = fleet4();
        disp.submit_hedged_lanes(rq(0, 0.0, 10.0), 2, 0.1, 2, 0.1);
    }

    // ------------------------------------- failure injection & timers

    #[test]
    fn timeout_pulls_a_stuck_request_for_requeue() {
        let mut disp = fleet4();
        disp.enable_timers();
        let mut exec = FixedExec { per_request_s: 1.0, residual: 0.0 };
        // rq 1 occupies lane 0's single worker until t=1.0; rq 2 (a
        // different length bucket, so it never joins the batch) is stuck
        // behind it.
        assert!(disp.submit_lane(0, rq(1, 0.0, 0.0)).is_admitted());
        assert!(disp.submit_lane(0, rq(2, 0.0, 10.0)).is_admitted());
        let done = collect_completions(&mut disp, &mut exec, 0.0);
        assert!(done.is_empty(), "nothing finishes at t=0");
        disp.arm_timeout(2, 0, 0.5);
        assert_eq!(disp.next_timeout_s(), Some(0.5));
        let mut fired = Vec::new();
        disp.fire_timeouts(0.5, &mut fired);
        assert_eq!(fired.len(), 1, "the stuck request times out");
        assert_eq!(fired[0].id, 2);
        assert_eq!(disp.next_timeout_s(), None);
        // Only rq 1 remains; the timed-out request left the queue and
        // reclaimed its backlog share.
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 1);
        assert!(disp.idle());
    }

    #[test]
    fn dispatched_request_leaves_a_stale_timer() {
        let mut disp = fleet4();
        disp.enable_timers();
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.0 };
        assert!(disp.submit_lane(0, rq(7, 0.0, 10.0)).is_admitted());
        disp.arm_timeout(7, 0, 2.0);
        // The request dispatches (and completes) long before its
        // deadline; the heap entry left behind must pop as a no-op.
        let done = collect_completions(&mut disp, &mut exec, 1.0);
        assert_eq!(done.len(), 1);
        let mut fired = Vec::new();
        disp.fire_timeouts(2.0, &mut fired);
        assert!(fired.is_empty(), "a dispatched request never times out");
    }

    #[test]
    fn fail_lane_kills_queued_and_in_flight_solo_requests() {
        let mut disp = fleet4();
        let mut exec = FixedExec { per_request_s: 1.0, residual: 0.0 };
        // rq 1 dispatches at t=0 (in flight until 1.0); rq 2 and rq 3
        // queue behind it in a different bucket.
        assert!(disp.submit_lane(0, rq(1, 0.0, 0.0)).is_admitted());
        assert!(disp.submit_lane(0, rq(2, 0.0, 10.0)).is_admitted());
        assert!(disp.submit_lane(0, rq(3, 0.0, 10.0)).is_admitted());
        let _ = collect_completions(&mut disp, &mut exec, 0.0);
        let mut killed = Vec::new();
        let n_inflight = disp.fail_lane(0, 0.5, &mut killed);
        assert_eq!(n_inflight, 1, "rq 1's batch was in flight");
        // Deterministic order: queued FIFO first, then in-flight.
        assert_eq!(
            killed.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![2, 3, 1]
        );
        assert!(disp.lane_down(0));
        // While down: admissions refuse, the lane never dispatches, and
        // its expected wait reads idle (the dead queue was wiped).
        assert!(!disp.submit_lane(0, rq(4, 0.6, 0.0)).is_admitted());
        assert_eq!(disp.expected_wait_lane(0, 0.5), 0.0);
        assert!(collect_completions(&mut disp, &mut exec, f64::INFINITY).is_empty());
        // After recovery the lane serves again, idle from `now`.
        disp.recover_lane(0, 30.5);
        assert!(!disp.lane_down(0));
        assert!(disp.submit_lane(0, rq(5, 30.5, 0.0)).is_admitted());
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 1);
        assert!((done[0].start_s - 30.5).abs() < 1e-12, "no phantom busy time");
        assert!(disp.idle());
    }

    #[test]
    fn fail_lane_spares_hedged_copies_whose_twin_is_alive() {
        let mut disp = fleet4();
        let mut exec = PerLaneExec { lane_s: vec![1.0, 0.5, 0.5, 0.5] };
        // Occupy lane 1 so the hedged copy there stays queued a while.
        assert!(disp.submit_lane(1, rq(1, 0.0, 0.0)).is_admitted());
        let out = disp.submit_hedged_lanes(rq(2, 0.0, 10.0), 0, 1.0, 1, 0.5);
        assert_eq!(out, LaneHedgeOutcome::Hedged);
        // Crash lane 0 before anything dispatches there at t=0: the
        // copy on lane 0 dies, but its twin on lane 1 is alive — the
        // request is NOT killed.
        let mut killed = Vec::new();
        let n_inflight = disp.fail_lane(0, 0.0, &mut killed);
        assert_eq!(n_inflight, 0);
        assert!(killed.is_empty(), "twin carries the request on");
        // The surviving twin completes as the race winner and the
        // arena entry is released (the destroyed copy can never be
        // lazily purged).
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        let wins: Vec<_> =
            done.iter().filter(|c| c.kind == CompletionKind::HedgeWin).collect();
        assert_eq!(wins.len(), 1);
        assert_eq!(wins[0].request.id, 2);
        assert_eq!(wins[0].lane, 1);
        assert_eq!(disp.hedges_in_flight(), 0, "arena leaks nothing");
    }

    #[test]
    fn fail_lane_closes_a_decided_race_straggler() {
        let mut disp = fleet4();
        let mut exec = PerLaneExec { lane_s: vec![0.2, 5.0, 0.5, 0.5] };
        let out = disp.submit_hedged_lanes(rq(9, 0.0, 10.0), 0, 0.2, 1, 5.0);
        assert_eq!(out, LaneHedgeOutcome::Hedged);
        // Both copies dispatch at t=0; lane 0 wins at 0.2, lane 1's
        // loser is still running until 5.0.
        let done = collect_completions(&mut disp, &mut exec, 0.3);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].kind, CompletionKind::HedgeWin);
        // Crash lane 1: the straggling loser is destroyed. The request
        // already has its result, so nothing is killed, and the race
        // entry closes without a loss completion.
        let mut killed = Vec::new();
        let n_inflight = disp.fail_lane(1, 0.3, &mut killed);
        assert_eq!(n_inflight, 1);
        assert!(killed.is_empty());
        assert!(collect_completions(&mut disp, &mut exec, f64::INFINITY).is_empty());
        assert_eq!(disp.hedges_in_flight(), 0);
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 1);
        assert_eq!(hs.wins_edge, 1);
        assert_eq!(hs.losers_run, 0, "the destroyed loser never completed");
    }

    #[test]
    fn double_fault_kills_the_request_once() {
        let mut disp = fleet4();
        let mut exec = PerLaneExec { lane_s: vec![1.0, 1.0, 0.5, 0.5] };
        // Park head-of-line blockers so the hedged copies stay queued.
        assert!(disp.submit_lane(0, rq(1, 0.0, 0.0)).is_admitted());
        assert!(disp.submit_lane(1, rq(2, 0.0, 0.0)).is_admitted());
        let _ = collect_completions(&mut disp, &mut exec, 0.0);
        let out = disp.submit_hedged_lanes(rq(3, 0.0, 10.0), 0, 1.0, 1, 1.0);
        assert_eq!(out, LaneHedgeOutcome::Hedged);
        let mut killed = Vec::new();
        disp.fail_lane(0, 0.1, &mut killed);
        // First fault: the in-flight blocker dies; the hedged copy's
        // twin survives, so rq 3 is not killed yet.
        assert_eq!(killed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        killed.clear();
        disp.fail_lane(1, 0.2, &mut killed);
        // Second fault ends rq 3 exactly once (queued copy, FIFO-first)
        // plus lane 1's in-flight blocker.
        assert_eq!(killed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 2]);
        assert_eq!(disp.hedges_in_flight(), 0);
    }

    #[test]
    fn down_lane_degrades_hedging_to_the_healthy_lane() {
        let mut disp = fleet4();
        let mut killed = Vec::new();
        disp.fail_lane(0, 0.0, &mut killed);
        let out = disp.submit_hedged_lanes(rq(1, 0.0, 10.0), 0, 0.1, 1, 0.1);
        assert_eq!(out, LaneHedgeOutcome::Single(1), "no race with a dead lane");
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.0 };
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].kind, CompletionKind::Solo);
    }
}
