//! Worker-pool dispatcher: drives the edge/cloud executors from the
//! admission queues.
//!
//! One lane per device: an [`AdmissionQueue`] plus a
//! [`CapacityTracker`] over a fixed worker pool (the edge gateway is
//! typically 1 worker — one serial execution stream, the discipline the
//! paper's latency model assumes — while the cloud server exposes
//! several). The dispatcher is clock-driven and backend-agnostic: it
//! owns *when* and *what* to run, a [`BatchExecutor`] owns *how long*
//! it takes — the simulation backs it with ground-truth tables
//! ([`crate::sim::harness`]), a live gateway would back it with real
//! engines.
//!
//! The dispatcher is a two-queue discrete-event loop: batch *starts*
//! (earliest ready batch across both lanes, edge winning ties) and batch
//! *completions* (a min-heap on finish time) are processed in global
//! simulated-time order, completions first on ties. This ordering is
//! what makes cross-lane interactions — a hedge winner on one lane
//! cancelling its twin on the other — causally correct: a twin can only
//! be cancelled by a completion that actually precedes its dispatch.
//!
//! ## Hedged dispatch
//!
//! When the router's expected-latency gap between edge and cloud is
//! inside its error bar, committing to either side is a coin flip;
//! [`submit_hedged`] instead enqueues a copy on *both* lanes under one
//! request id. The first copy to **finish** is the request's result
//! ([`CompletionKind::HedgeWin`]); the twin is cancelled. A twin still
//! queued is purged without running and its backlog share reclaimed
//! ([`CapacityTracker::on_cancel`]); a twin already executing runs to
//! completion as wasted work ([`CompletionKind::HedgeLoss`]).
//! [`HedgeStats`] counts every outcome.
//!
//! ## Zero-churn hot path
//!
//! In-flight hedge races live in a generational slab arena
//! ([`crate::util::Slab`]); each queued copy carries its race's
//! [`crate::util::SlabKey`], so completion classification and
//! cancellation are direct, generation-checked array accesses — the old
//! id-keyed `HashMap`/`HashSet` pair (one to three hashes per
//! completion, heap churn under load) is gone, and a cancelled twin is
//! marked *in* its race entry rather than in a side set. Batches form
//! into a scratch buffer reused across dispatches, the admission queues
//! sit on ring buffers, and the pending-completion min-heap stores
//! `Copy` records — once warmed to its peak population the whole
//! dispatch path performs **zero heap allocations**, asserted by the
//! counting-allocator test in `tests/alloc_steady_state.rs`.
//!
//! The per-request hot path (`expected_wait_s` → route → [`submit`]) is
//! O(1) for a fixed worker pool: no allocation, no queue scans.
//! Dispatch itself ([`run_until`]) is amortised O(log inflight) per
//! request (heap push/pop); cancellation is O(1).
//!
//! [`submit`]: Dispatcher::submit
//! [`submit_hedged`]: Dispatcher::submit_hedged
//! [`run_until`]: Dispatcher::run_until

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::devices::DeviceKind;
use crate::util::{Slab, SlabKey};

use super::batch::{BatchPolicy, BatchStats};
use super::capacity::CapacityTracker;
use super::queue::{Admission, AdmissionQueue, QueueStats, QueuedRequest};

/// Service-time backend: how long a batch runs on a device.
pub trait BatchExecutor {
    /// Service seconds for `batch` started at `start_s` on `device`.
    /// `batch` is non-empty.
    fn execute(
        &mut self,
        device: DeviceKind,
        batch: &[QueuedRequest],
        start_s: f64,
    ) -> f64;
}

/// Dispatcher sizing parameters.
#[derive(Debug, Clone, Copy)]
pub struct DispatcherConfig {
    /// Edge worker slots (the gateway's serial executor ⇒ usually 1).
    pub edge_workers: usize,
    /// Cloud worker slots.
    pub cloud_workers: usize,
    /// Per-device admission-queue depth bound.
    pub max_queue_depth: usize,
    /// Micro-batching policy (shared by both lanes).
    pub batch: BatchPolicy,
}

impl Default for DispatcherConfig {
    fn default() -> Self {
        DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 4,
            max_queue_depth: 512,
            batch: BatchPolicy::default(),
        }
    }
}

/// How a completed copy relates to its request (hedging outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionKind {
    /// The request's only submission: this completion is its result.
    Solo,
    /// Hedged, and this copy finished first: the request's result. The
    /// twin has been cancelled (purged if still queued).
    HedgeWin,
    /// Hedged, and the twin already won: this copy's work is wasted.
    /// Never count it toward goodput.
    HedgeLoss,
}

impl CompletionKind {
    /// Is this completion the request's result (vs duplicated waste)?
    pub fn is_result(&self) -> bool {
        !matches!(self, CompletionKind::HedgeLoss)
    }
}

/// One completed request copy, reported through [`Dispatcher::run_until`]
/// in nondecreasing `done_s` order.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// The queued request (hedge twins share `id`/`payload`).
    pub request: QueuedRequest,
    /// Device the copy ran on.
    pub device: DeviceKind,
    /// When its batch started executing.
    pub start_s: f64,
    /// When its batch finished (= response time at the device).
    pub done_s: f64,
    /// Size of the batch it rode in.
    pub batch_size: usize,
    /// Hedging outcome ([`CompletionKind::Solo`] for normal submissions).
    pub kind: CompletionKind,
}

/// Hedged-dispatch counters kept by the dispatcher.
///
/// Invariants once drained: `wins_edge + wins_cloud == hedged`, and every
/// hedged request resolves its twin exactly one way —
/// `cancelled_unrun + losers_run == hedged`.
#[derive(Debug, Clone, Copy, Default)]
pub struct HedgeStats {
    /// Requests actually duplicated (both copies admitted).
    pub hedged: u64,
    /// Hedged requests whose edge copy finished first.
    pub wins_edge: u64,
    /// Hedged requests whose cloud copy finished first.
    pub wins_cloud: u64,
    /// Losing twins cancelled while still queued (no work wasted).
    pub cancelled_unrun: u64,
    /// Losing twins that were already executing and ran to completion
    /// (wasted work).
    pub losers_run: u64,
}

/// Outcome of a hedged submission ([`Dispatcher::submit_hedged`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HedgeOutcome {
    /// Both copies admitted: the request is racing on both lanes.
    Hedged,
    /// Only one lane had room: degraded to a normal submission there.
    Single(DeviceKind),
    /// Both lanes full: the request was shed.
    Rejected,
}

/// Lifecycle of one hedged copy on its lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CopyState {
    Queued,
    Running,
    Done,
    /// Cancelled while still queued (its twin won): a ghost awaiting
    /// lazy purge. Marked here, in the race entry itself — there is no
    /// side table of cancel tokens to hash into.
    Cancelled,
}

/// Dispatcher-side state of one in-flight hedge race (a slab entry;
/// both queued copies carry its key).
#[derive(Debug, Clone, Copy)]
struct HedgeEntry {
    /// Per-lane service estimate (`[edge, cloud]`) — needed to reclaim
    /// backlog when the queued twin is cancelled.
    est: [f64; 2],
    state: [CopyState; 2],
    winner: Option<DeviceKind>,
}

/// A dispatched copy waiting for its finish event to fire. Ordered by
/// `(done_s, seq)` — `seq` makes equal finish times resolve in dispatch
/// order, deterministically.
#[derive(Debug, Clone, Copy)]
struct Pending {
    done_s: f64,
    seq: u64,
    start_s: f64,
    batch_size: usize,
    device: DeviceKind,
    request: QueuedRequest,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.done_s == other.done_s && self.seq == other.seq
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.done_s
            .total_cmp(&other.done_s)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Queue + capacity state for one device (internal to the dispatcher).
#[derive(Debug, Clone)]
struct Lane {
    queue: AdmissionQueue,
    tracker: CapacityTracker,
}

impl Lane {
    fn new(workers: usize, max_depth: usize) -> Self {
        Lane {
            queue: AdmissionQueue::new(max_depth),
            tracker: CapacityTracker::new(workers),
        }
    }

    /// Admit + account in one step.
    fn offer(&mut self, rq: QueuedRequest) -> Admission {
        let admission = self.queue.offer(rq);
        if admission.is_admitted() {
            self.tracker.on_admit(rq.est_service_s);
        }
        admission
    }
}

fn lane_idx(device: DeviceKind) -> usize {
    match device {
        DeviceKind::Edge => 0,
        DeviceKind::Cloud => 1,
    }
}

fn other(device: DeviceKind) -> DeviceKind {
    match device {
        DeviceKind::Edge => DeviceKind::Cloud,
        DeviceKind::Cloud => DeviceKind::Edge,
    }
}

/// Is `rq` a cancelled hedge ghost on lane `li`? (Generation-checked
/// arena lookup; false for solo requests and live copies.)
fn is_ghost(hedges: &Slab<HedgeEntry>, rq: &QueuedRequest, li: usize) -> bool {
    match rq.hedge {
        Some(key) => matches!(
            hedges.get(key),
            Some(entry) if entry.state[li] == CopyState::Cancelled
        ),
        None => false,
    }
}

/// The two-lane edge/cloud dispatcher.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    edge: Lane,
    cloud: Lane,
    policy: BatchPolicy,
    stats: BatchStats,
    /// Dispatched copies whose finish events have not fired yet
    /// (min-heap on finish time; `Copy` entries, capacity reused).
    pending: BinaryHeap<Reverse<Pending>>,
    seq: u64,
    /// In-flight hedge races (slab arena; keys live in the queued
    /// copies, so no per-completion hashing).
    hedges: Slab<HedgeEntry>,
    /// Scratch buffer batches form into (reused across dispatches).
    scratch: Vec<QueuedRequest>,
    hedge_stats: HedgeStats,
}

impl Dispatcher {
    /// Build a dispatcher from its sizing parameters.
    pub fn new(cfg: &DispatcherConfig) -> Self {
        Dispatcher {
            edge: Lane::new(cfg.edge_workers, cfg.max_queue_depth),
            cloud: Lane::new(cfg.cloud_workers, cfg.max_queue_depth),
            policy: cfg.batch,
            stats: BatchStats::default(),
            pending: BinaryHeap::with_capacity(64),
            seq: 0,
            hedges: Slab::with_capacity(16),
            scratch: Vec::with_capacity(cfg.batch.max_batch.max(1)),
            hedge_stats: HedgeStats::default(),
        }
    }

    fn lane(&self, device: DeviceKind) -> &Lane {
        match device {
            DeviceKind::Edge => &self.edge,
            DeviceKind::Cloud => &self.cloud,
        }
    }

    fn lane_mut(&mut self, device: DeviceKind) -> &mut Lane {
        match device {
            DeviceKind::Edge => &mut self.edge,
            DeviceKind::Cloud => &mut self.cloud,
        }
    }

    /// Expected queueing delay on `device` for a request arriving now —
    /// the router adds this to each side of eq. 1.
    #[inline]
    pub fn expected_wait_s(&self, device: DeviceKind, now_s: f64) -> f64 {
        let lane = self.lane(device);
        lane.tracker.expected_wait_s(now_s)
    }

    /// Admit a request to `device`'s queue (O(1), allocation-free once
    /// warmed). The request's bucket is assigned here so queue and
    /// batcher always agree on it; the hedge key is dispatcher-owned
    /// and cleared for solo submissions.
    pub fn submit(&mut self, device: DeviceKind, mut rq: QueuedRequest) -> Admission {
        rq.bucket = self.policy.bucket_of(rq.m_est);
        rq.hedge = None;
        self.lane_mut(device).offer(rq)
    }

    /// Hedged submission: enqueue a copy of `rq` on *both* lanes, with
    /// per-lane service estimates (the copies differ only in
    /// `est_service_s`). First copy to finish wins; the loser is
    /// cancelled ([`CompletionKind`]). If only one lane admits, the
    /// request degrades to a normal submission there; if neither does,
    /// it is shed. O(1).
    pub fn submit_hedged(
        &mut self,
        mut rq: QueuedRequest,
        edge_est_s: f64,
        cloud_est_s: f64,
    ) -> HedgeOutcome {
        rq.bucket = self.policy.bucket_of(rq.m_est);
        rq.hedge = None;
        // Room is checked up front so the race entry is allocated only
        // when both copies are expected to be admitted (`offer` applies
        // the same live-depth predicate today).
        if self.edge.queue.has_room() && self.cloud.queue.has_room() {
            let key = self.hedges.insert(HedgeEntry {
                est: [edge_est_s, cloud_est_s],
                state: [CopyState::Queued, CopyState::Queued],
                winner: None,
            });
            rq.hedge = Some(key);
            let mut edge_rq = rq;
            edge_rq.est_service_s = edge_est_s;
            let mut cloud_rq = rq;
            cloud_rq.est_service_s = cloud_est_s;
            let edge_ok = self.edge.offer(edge_rq).is_admitted();
            let cloud_ok = self.cloud.offer(cloud_rq).is_admitted();
            if edge_ok && cloud_ok {
                self.hedge_stats.hedged += 1;
                return HedgeOutcome::Hedged;
            }
            // Defensive unwind: unreachable today, but if `offer` ever
            // grows a shed condition `has_room` doesn't know about, the
            // race must not half-exist. Freeing the entry makes any
            // admitted copy's key stale, and a stale key is inert — the
            // generation check classifies its completion as Solo and it
            // can never be mistaken for a ghost.
            self.hedges.remove(key);
            return match (edge_ok, cloud_ok) {
                (true, false) => HedgeOutcome::Single(DeviceKind::Edge),
                (false, true) => HedgeOutcome::Single(DeviceKind::Cloud),
                _ => HedgeOutcome::Rejected,
            };
        }
        // Degraded path: offer both copies anyway (the full lane counts
        // the rejection, exactly as a solo offer would).
        let mut edge_rq = rq;
        edge_rq.est_service_s = edge_est_s;
        let mut cloud_rq = rq;
        cloud_rq.est_service_s = cloud_est_s;
        let edge_ok = self.edge.offer(edge_rq).is_admitted();
        let cloud_ok = self.cloud.offer(cloud_rq).is_admitted();
        match (edge_ok, cloud_ok) {
            (true, false) => HedgeOutcome::Single(DeviceKind::Edge),
            (false, true) => HedgeOutcome::Single(DeviceKind::Cloud),
            (false, false) => HedgeOutcome::Rejected,
            // `offer` rejects whenever `has_room` is false (it is the
            // same predicate), so both lanes admitting after at least
            // one reported no room is an internal-invariant breach —
            // two unkeyed copies of one request would double-count.
            // Fail loudly rather than corrupt the accounting.
            (true, true) => unreachable!("offer admitted where has_room denied"),
        }
    }

    /// Queue depth on `device` (includes not-yet-purged cancelled twins).
    pub fn depth(&self, device: DeviceKind) -> usize {
        self.lane(device).queue.depth()
    }

    /// Admission counters for `device`'s queue. Hedged submissions offer
    /// one copy per lane, so `offered` counts copies, not requests.
    pub fn queue_stats(&self, device: DeviceKind) -> QueueStats {
        self.lane(device).queue.stats()
    }

    /// Micro-batch size accounting across both lanes.
    pub fn batch_stats(&self) -> BatchStats {
        self.stats
    }

    /// Hedged-dispatch outcome counters.
    pub fn hedge_stats(&self) -> HedgeStats {
        self.hedge_stats
    }

    /// Hedge races whose bookkeeping is still open (both copies pending,
    /// a loser still running, or a cancelled ghost awaiting purge).
    /// Zero once the dispatcher is drained — the arena leaks nothing.
    pub fn hedges_in_flight(&self) -> usize {
        self.hedges.len()
    }

    /// No queued work and no in-flight batches?
    pub fn idle(&self) -> bool {
        self.edge.queue.is_empty() && self.cloud.queue.is_empty() && self.pending.is_empty()
    }

    /// Time of the next event (batch start or batch completion), if any
    /// work is queued or in flight. Purges cancelled entries at the
    /// queue heads as a side effect. External event loops (closed-loop
    /// clients) interleave their submissions with this clock.
    pub fn next_event_s(&mut self) -> Option<f64> {
        let next_start = self.next_batch_start().map(|(_d, s)| s);
        let next_done = self.pending.peek().map(|p| p.0.done_s);
        match (next_start, next_done) {
            (None, None) => None,
            (Some(s), None) => Some(s),
            (None, Some(t)) => Some(t),
            (Some(s), Some(t)) => Some(s.min(t)),
        }
    }

    /// Earliest batch start across both lanes (edge wins ties).
    fn next_batch_start(&mut self) -> Option<(DeviceKind, f64)> {
        let e = self.lane_next_start(DeviceKind::Edge);
        let c = self.lane_next_start(DeviceKind::Cloud);
        match (e, c) {
            (None, None) => None,
            (Some(s), None) => Some((DeviceKind::Edge, s)),
            (None, Some(s)) => Some((DeviceKind::Cloud, s)),
            (Some(se), Some(sc)) => {
                if se <= sc {
                    Some((DeviceKind::Edge, se))
                } else {
                    Some((DeviceKind::Cloud, sc))
                }
            }
        }
    }

    /// Start time of `device`'s next batch (max of head arrival and the
    /// earliest-free worker), purging cancelled heads on the way.
    fn lane_next_start(&mut self, device: DeviceKind) -> Option<f64> {
        let li = lane_idx(device);
        let (lane, hedges) = match device {
            DeviceKind::Edge => (&mut self.edge, &mut self.hedges),
            DeviceKind::Cloud => (&mut self.cloud, &mut self.hedges),
        };
        loop {
            let head = match lane.queue.peek() {
                None => return None,
                Some(h) => *h,
            };
            if is_ghost(hedges, &head, li) {
                lane.queue.pop();
                lane.queue.unmark_dead();
                // The race is fully resolved once its ghost is gone:
                // free the arena entry (slot recycled, key goes stale).
                hedges.remove(head.hedge.expect("ghost carries its key"));
                continue;
            }
            let (_worker, free_s) = lane.tracker.earliest_free();
            return Some(free_s.max(head.arrival_s));
        }
    }

    /// Process the single earliest event — a batch completion or a batch
    /// start, completions first on ties — if it happens at or before
    /// `horizon_s`. Returns whether an event was processed;
    /// `on_complete` fires once per finished copy, in nondecreasing
    /// finish-time order.
    pub fn step<E, F>(&mut self, horizon_s: f64, exec: &mut E, on_complete: &mut F) -> bool
    where
        E: BatchExecutor,
        F: FnMut(Completion),
    {
        let next_start = self.next_batch_start();
        let next_done = self.pending.peek().map(|p| p.0.done_s);
        let completion_first = match (next_start, next_done) {
            (None, None) => return false,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (Some((_d, s)), Some(t)) => t <= s,
        };
        if completion_first {
            let done_s = next_done.expect("peeked completion exists");
            if done_s > horizon_s {
                return false;
            }
            self.flush_one(on_complete);
        } else {
            let (device, start_s) = next_start.expect("peeked start exists");
            if start_s > horizon_s {
                return false;
            }
            self.dispatch_at(device, start_s, exec);
        }
        true
    }

    /// Process every event (on both lanes, in global simulated-time
    /// order) up to and including `horizon_s`; `on_complete` fires once
    /// per finished copy. Drive with `horizon_s = next arrival time`
    /// while feeding arrivals, then once with `f64::INFINITY` to drain.
    pub fn run_until<E, F>(&mut self, horizon_s: f64, exec: &mut E, on_complete: &mut F)
    where
        E: BatchExecutor,
        F: FnMut(Completion),
    {
        while self.step(horizon_s, exec, on_complete) {}
    }

    /// Form + execute one batch on `device` at `start_s`, pushing its
    /// members onto the pending-completion heap. Allocation-free once
    /// warmed: the batch forms into the reused scratch buffer and ghost
    /// purges recycle their arena slots.
    fn dispatch_at<E>(&mut self, device: DeviceKind, start_s: f64, exec: &mut E)
    where
        E: BatchExecutor,
    {
        let li = lane_idx(device);
        let mut batch = std::mem::take(&mut self.scratch);
        {
            let (lane, hedges) = match device {
                DeviceKind::Edge => (&mut self.edge, &mut self.hedges),
                DeviceKind::Cloud => (&mut self.cloud, &mut self.hedges),
            };
            self.policy
                .form_batch_into(&mut lane.queue, start_s, &mut batch, |rq| {
                    if is_ghost(hedges, rq, li) {
                        hedges.remove(rq.hedge.expect("ghost carries its key"));
                        true
                    } else {
                        false
                    }
                });
        }
        if batch.is_empty() {
            self.scratch = batch;
            return;
        }
        // Hedged members are now executing: too late to cancel them.
        for rq in &batch {
            if let Some(key) = rq.hedge {
                if let Some(entry) = self.hedges.get_mut(key) {
                    entry.state[li] = CopyState::Running;
                }
            }
        }
        let est_sum: f64 = batch.iter().map(|r| r.est_service_s).sum();
        let service_s = exec.execute(device, &batch, start_s).max(0.0);
        let done_s = start_s + service_s;
        {
            let lane = self.lane_mut(device);
            let (worker, _free) = lane.tracker.earliest_free();
            lane.tracker.on_dispatch(worker, est_sum, done_s);
        }
        self.stats.record(batch.len());
        let batch_size = batch.len();
        for request in batch.drain(..) {
            let seq = self.seq;
            self.seq += 1;
            self.pending.push(Reverse(Pending {
                done_s,
                seq,
                start_s,
                batch_size,
                device,
                request,
            }));
        }
        self.scratch = batch;
    }

    /// Fire the earliest pending completion event.
    fn flush_one<F>(&mut self, on_complete: &mut F)
    where
        F: FnMut(Completion),
    {
        let Reverse(p) = self.pending.pop().expect("pending completion exists");
        let kind = self.resolve_completion(p.device, p.request.hedge);
        on_complete(Completion {
            request: p.request,
            device: p.device,
            start_s: p.start_s,
            done_s: p.done_s,
            batch_size: p.batch_size,
            kind,
        });
    }

    /// Classify one finished copy and update the hedge bookkeeping:
    /// first finisher wins and cancels its twin (reclaiming queued
    /// capacity); a later finisher is wasted work. All O(1) — one
    /// generation-checked arena access, no hashing.
    fn resolve_completion(&mut self, device: DeviceKind, hedge: Option<SlabKey>) -> CompletionKind {
        let key = match hedge {
            None => return CompletionKind::Solo,
            Some(k) => k,
        };
        let di = lane_idx(device);
        let ti = lane_idx(other(device));
        let (kind, cancel_est) = match self.hedges.get_mut(key) {
            // Unreachable in practice (a dispatched copy's race entry
            // outlives it); treat a stale key as a solo completion.
            None => return CompletionKind::Solo,
            Some(entry) => {
                entry.state[di] = CopyState::Done;
                if entry.winner.is_some() {
                    (CompletionKind::HedgeLoss, None)
                } else {
                    entry.winner = Some(device);
                    if entry.state[ti] == CopyState::Queued {
                        // Twin still queued: mark it cancelled in the
                        // race entry itself. The ghost is purged lazily
                        // (queue head / batcher lookahead), which also
                        // frees this entry.
                        entry.state[ti] = CopyState::Cancelled;
                        (CompletionKind::HedgeWin, Some(entry.est[ti]))
                    } else {
                        // Twin running: keep the entry so its completion
                        // is classified as a loss.
                        (CompletionKind::HedgeWin, None)
                    }
                }
            }
        };
        match kind {
            CompletionKind::HedgeLoss => {
                // Twin already won; the race is fully resolved.
                self.hedges.remove(key);
                self.hedge_stats.losers_run += 1;
            }
            CompletionKind::HedgeWin => {
                match device {
                    DeviceKind::Edge => self.hedge_stats.wins_edge += 1,
                    DeviceKind::Cloud => self.hedge_stats.wins_cloud += 1,
                }
                if let Some(est) = cancel_est {
                    // Reclaim the cancelled twin's backlog share and
                    // admission slot now; the entry itself stays until
                    // the ghost is physically purged.
                    self.hedge_stats.cancelled_unrun += 1;
                    let lane = self.lane_mut(other(device));
                    lane.tracker.on_cancel(est);
                    lane.queue.mark_dead();
                }
            }
            CompletionKind::Solo => {}
        }
        kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fixed per-request time, batch = max + residual·rest.
    struct FixedExec {
        per_request_s: f64,
        residual: f64,
    }

    impl BatchExecutor for FixedExec {
        fn execute(&mut self, _d: DeviceKind, batch: &[QueuedRequest], _s: f64) -> f64 {
            let each = self.per_request_s;
            each + self.residual * each * (batch.len() - 1) as f64
        }
    }

    /// Per-device fixed batch time.
    struct AsymExec {
        edge_s: f64,
        cloud_s: f64,
    }

    impl BatchExecutor for AsymExec {
        fn execute(&mut self, d: DeviceKind, _batch: &[QueuedRequest], _s: f64) -> f64 {
            match d {
                DeviceKind::Edge => self.edge_s,
                DeviceKind::Cloud => self.cloud_s,
            }
        }
    }

    fn rq(id: u64, arrival_s: f64, m_est: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            payload: id as usize,
            n: 10,
            m_est,
            est_service_s: 0.1,
            arrival_s,
            bucket: 0, // overwritten by submit()
            hedge: None,
        }
    }

    fn collect_completions<E: BatchExecutor>(
        disp: &mut Dispatcher,
        exec: &mut E,
        horizon_s: f64,
    ) -> Vec<Completion> {
        let mut out = Vec::new();
        disp.run_until(horizon_s, exec, &mut |c| out.push(c));
        out
    }

    #[test]
    fn lone_request_runs_immediately_without_batching_delay() {
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.2 };
        assert!(disp.submit(DeviceKind::Edge, rq(0, 1.0, 10.0)).is_admitted());
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 1);
        assert!((done[0].start_s - 1.0).abs() < 1e-12);
        assert!((done[0].done_s - 1.1).abs() < 1e-12);
        assert_eq!(done[0].batch_size, 1);
        assert_eq!(done[0].kind, CompletionKind::Solo);
        assert!(disp.idle());
    }

    #[test]
    fn backlog_batches_and_amortises() {
        // One edge worker, four same-bucket requests arriving together:
        // they ride one batch and finish far sooner than serially.
        let cfg = DispatcherConfig { edge_workers: 1, ..Default::default() };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.2 };
        for i in 0..4 {
            disp.submit(DeviceKind::Edge, rq(i, 0.0, 10.0));
        }
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 4);
        assert_eq!(done[0].batch_size, 4);
        // 0.1 + 3·0.02 = 0.16 ≪ 0.4 serial.
        assert!((done[0].done_s - 0.16).abs() < 1e-9);
        assert!((disp.batch_stats().mean_batch_size() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn completions_fire_at_finish_time_not_dispatch_time() {
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.0 };
        disp.submit(DeviceKind::Cloud, rq(0, 5.0, 10.0));
        assert!(collect_completions(&mut disp, &mut exec, 4.9).is_empty());
        // At horizon 5.0 the batch starts (worker busy) but its finish
        // event at 5.1 has not fired yet.
        assert!(collect_completions(&mut disp, &mut exec, 5.0).is_empty());
        assert!(disp.expected_wait_s(DeviceKind::Cloud, 5.0) > 0.0);
        let done = collect_completions(&mut disp, &mut exec, 5.1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].device, DeviceKind::Cloud);
        assert!(disp.idle());
    }

    #[test]
    fn dispatch_order_is_global_start_time() {
        // The cloud head arrives before the edge head: cloud dispatches
        // (and completes) first even though edge is lane 0.
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.01, residual: 0.0 };
        disp.submit(DeviceKind::Edge, rq(0, 2.0, 10.0));
        disp.submit(DeviceKind::Cloud, rq(1, 1.0, 10.0));
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].device, DeviceKind::Cloud);
        assert_eq!(done[1].device, DeviceKind::Edge);
        assert!(done[0].done_s <= done[1].done_s);
    }

    #[test]
    fn expected_wait_rises_with_backlog_and_falls_with_workers() {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 4,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        for i in 0..8 {
            disp.submit(DeviceKind::Edge, rq(i, 0.0, 10.0));
            disp.submit(DeviceKind::Cloud, rq(100 + i, 0.0, 10.0));
        }
        let we = disp.expected_wait_s(DeviceKind::Edge, 0.0);
        let wc = disp.expected_wait_s(DeviceKind::Cloud, 0.0);
        assert!((we - 0.8).abs() < 1e-12, "edge wait {we}");
        assert!((wc - 0.2).abs() < 1e-12, "cloud wait {wc}");
    }

    #[test]
    fn conservation_admitted_equals_completed() {
        let cfg = DispatcherConfig {
            max_queue_depth: 16,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = FixedExec { per_request_s: 0.05, residual: 0.1 };
        let mut completed = 0usize;
        let mut rejected = 0usize;
        for i in 0..200u64 {
            let t = i as f64 * 0.01;
            disp.run_until(t, &mut exec, &mut |_c| completed += 1);
            let dev = if i % 3 == 0 { DeviceKind::Edge } else { DeviceKind::Cloud };
            if !disp.submit(dev, rq(i, t, (i % 40) as f64)).is_admitted() {
                rejected += 1;
            }
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut |_c| completed += 1);
        assert_eq!(completed + rejected, 200);
        let qs_e = disp.queue_stats(DeviceKind::Edge);
        let qs_c = disp.queue_stats(DeviceKind::Cloud);
        assert_eq!(qs_e.offered + qs_c.offered, 200);
        assert_eq!(qs_e.rejected + qs_c.rejected, rejected as u64);
        assert!(disp.idle());
    }

    #[test]
    fn hedge_winner_cancels_queued_twin() {
        // Cloud is busy behind a long job, so the hedged cloud copy is
        // still queued when the edge copy finishes: it must be purged
        // without running and its backlog share reclaimed.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.1, cloud_s: 5.0 };
        disp.submit(DeviceKind::Cloud, rq(0, 0.0, 10.0)); // 5 s blocker
        assert_eq!(
            disp.submit_hedged(rq(1, 0.1, 10.0), 0.1, 0.1),
            HedgeOutcome::Hedged
        );
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        // Blocker + edge win; the cloud twin never executes.
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].kind, CompletionKind::HedgeWin);
        assert_eq!(done[0].device, DeviceKind::Edge);
        assert_eq!(done[0].request.id, 1);
        assert_eq!(done[1].kind, CompletionKind::Solo);
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 1);
        assert_eq!(hs.wins_edge, 1);
        assert_eq!(hs.cancelled_unrun, 1);
        assert_eq!(hs.losers_run, 0);
        assert!(disp.idle());
        assert_eq!(disp.hedges_in_flight(), 0, "drained arena must be empty");
        // Backlog fully reclaimed once drained.
        assert_eq!(disp.expected_wait_s(DeviceKind::Cloud, 100.0), 0.0);
    }

    #[test]
    fn hedge_winner_is_first_finisher_not_first_dispatched() {
        // Both lanes idle: both copies start at t=0 (edge dispatched
        // first), but the cloud copy finishes sooner — it must win, and
        // the already-running edge copy completes as wasted work.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.5, cloud_s: 0.1 };
        assert_eq!(
            disp.submit_hedged(rq(0, 0.0, 10.0), 0.5, 0.1),
            HedgeOutcome::Hedged
        );
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].kind, CompletionKind::HedgeWin);
        assert_eq!(done[0].device, DeviceKind::Cloud);
        assert_eq!(done[1].kind, CompletionKind::HedgeLoss);
        assert_eq!(done[1].device, DeviceKind::Edge);
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 1);
        assert_eq!(hs.wins_cloud, 1);
        assert_eq!(hs.losers_run, 1);
        assert_eq!(hs.cancelled_unrun, 0);
        assert_eq!(disp.hedges_in_flight(), 0);
    }

    #[test]
    fn queued_twin_that_starts_before_winner_finishes_still_races() {
        // Edge copy starts at 0 and takes 5 s; the cloud twin is queued
        // behind a 1 s blocker, starts at 1.0 — *before* the edge copy
        // finishes — so it must not be cancelled, and it wins at 1.1.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 5.0, cloud_s: 1.0 };
        // Different bucket (m_est 30 vs 10) so the twin cannot ride the
        // blocker's batch: it genuinely waits, then starts at 1.0.
        disp.submit(DeviceKind::Cloud, rq(0, 0.0, 30.0)); // blocker, done 1.0
        assert_eq!(
            disp.submit_hedged(rq(1, 0.0, 10.0), 5.0, 1.0),
            HedgeOutcome::Hedged
        );
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        let kinds: Vec<(u64, CompletionKind)> =
            done.iter().map(|c| (c.request.id, c.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                (0, CompletionKind::Solo),      // blocker finishes at 1.0
                (1, CompletionKind::HedgeWin),  // cloud twin finishes at 2.0
                (1, CompletionKind::HedgeLoss), // edge copy finishes at 5.0
            ]
        );
        let hs = disp.hedge_stats();
        assert_eq!(hs.wins_cloud, 1);
        assert_eq!(hs.losers_run, 1);
        assert_eq!(hs.cancelled_unrun, 0);
        assert_eq!(disp.hedges_in_flight(), 0);
    }

    #[test]
    fn cancelled_twin_frees_its_admission_slot() {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            max_queue_depth: 3,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.1, cloud_s: 10.0 };
        disp.submit(DeviceKind::Cloud, rq(0, 0.0, 30.0)); // blocker, 10 s
        disp.submit(DeviceKind::Cloud, rq(1, 0.0, 20.0)); // queued solo
        assert_eq!(
            disp.submit_hedged(rq(2, 0.0, 10.0), 0.1, 0.1),
            HedgeOutcome::Hedged
        );
        let mut comps = Vec::new();
        disp.run_until(0.5, &mut exec, &mut |c| comps.push(c));
        // Edge copy won at 0.1; the cloud twin sits mid-queue as a
        // cancelled ghost: physically present, but its admission slot
        // is released.
        assert_eq!(disp.hedge_stats().cancelled_unrun, 1);
        assert_eq!(disp.depth(DeviceKind::Cloud), 2);
        assert!(disp.submit(DeviceKind::Cloud, rq(3, 0.6, 20.0)).is_admitted());
        assert!(disp.submit(DeviceKind::Cloud, rq(4, 0.7, 20.0)).is_admitted());
        // Three live entries now: the bound holds again.
        assert!(!disp.submit(DeviceKind::Cloud, rq(5, 0.8, 20.0)).is_admitted());
        disp.run_until(f64::INFINITY, &mut exec, &mut |c| comps.push(c));
        assert!(disp.idle());
        assert_eq!(disp.hedges_in_flight(), 0, "purged ghost must free its entry");
        let results = comps.iter().filter(|c| c.kind.is_result()).count();
        assert_eq!(results, 5, "4 solos + 1 hedge winner");
    }

    #[test]
    fn hedge_degrades_to_single_when_one_lane_is_full() {
        let cfg = DispatcherConfig { max_queue_depth: 1, ..Default::default() };
        let mut disp = Dispatcher::new(&cfg);
        disp.submit(DeviceKind::Edge, rq(0, 0.0, 10.0)); // fills edge
        match disp.submit_hedged(rq(1, 0.0, 10.0), 0.1, 0.1) {
            HedgeOutcome::Single(DeviceKind::Cloud) => {}
            o => panic!("expected Single(Cloud), got {o:?}"),
        }
        assert_eq!(disp.hedge_stats().hedged, 0);
        assert_eq!(disp.hedges_in_flight(), 0, "degraded hedge must not leak");
        // Both lanes full now: the next hedge is shed outright.
        assert_eq!(
            disp.submit_hedged(rq(2, 0.0, 10.0), 0.1, 0.1),
            HedgeOutcome::Rejected
        );
    }

    #[test]
    fn recycled_arena_slots_never_confuse_races() {
        // Run many sequential hedge races through a 1-entry-deep arena:
        // every race recycles the same physical slot, and the generation
        // check must keep each resolution tied to its own race.
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = AsymExec { edge_s: 0.01, cloud_s: 0.5 };
        let mut wins = 0u64;
        for i in 0..50u64 {
            let t = i as f64;
            disp.run_until(t, &mut exec, &mut |c| {
                if c.kind == CompletionKind::HedgeWin {
                    wins += 1;
                }
            });
            assert_eq!(
                disp.submit_hedged(rq(i, t, 10.0), 0.01, 0.5),
                HedgeOutcome::Hedged
            );
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut |c| {
            if c.kind == CompletionKind::HedgeWin {
                wins += 1;
            }
        });
        assert_eq!(wins, 50, "every race has exactly one winner");
        let hs = disp.hedge_stats();
        assert_eq!(hs.hedged, 50);
        assert_eq!(hs.wins_edge + hs.wins_cloud, 50);
        assert_eq!(hs.cancelled_unrun + hs.losers_run, 50);
        assert_eq!(disp.hedges_in_flight(), 0);
        assert!(disp.idle());
    }
}
