//! Worker-pool dispatcher: drives the edge/cloud executors from the
//! admission queues.
//!
//! One lane per device: an [`AdmissionQueue`] plus a
//! [`CapacityTracker`] over a fixed worker pool (the edge gateway is
//! typically 1 worker — one serial execution stream, the discipline the
//! paper's latency model assumes — while the cloud server exposes
//! several). The dispatcher is clock-driven and backend-agnostic: it
//! owns *when* and *what* to run, a [`BatchExecutor`] owns *how long*
//! it takes — the simulation backs it with ground-truth tables
//! ([`crate::sim::harness`]), a live gateway would back it with real
//! engines.
//!
//! The per-request hot path (`expected_wait_s` → route → [`submit`]) is
//! O(1) for a fixed worker pool: no allocation, no queue scans.
//! Dispatch itself ([`run_until`]) is amortised O(1) per request via the
//! bounded-lookahead batcher.
//!
//! [`submit`]: Dispatcher::submit
//! [`run_until`]: Dispatcher::run_until

use crate::devices::DeviceKind;

use super::batch::{BatchPolicy, BatchStats};
use super::capacity::CapacityTracker;
use super::queue::{Admission, AdmissionQueue, QueueStats, QueuedRequest};

/// Service-time backend: how long a batch runs on a device.
pub trait BatchExecutor {
    /// Service seconds for `batch` started at `start_s` on `device`.
    /// `batch` is non-empty.
    fn execute(
        &mut self,
        device: DeviceKind,
        batch: &[QueuedRequest],
        start_s: f64,
    ) -> f64;
}

/// Dispatcher sizing parameters.
#[derive(Debug, Clone, Copy)]
pub struct DispatcherConfig {
    /// Edge worker slots (the gateway's serial executor ⇒ usually 1).
    pub edge_workers: usize,
    /// Cloud worker slots.
    pub cloud_workers: usize,
    /// Per-device admission-queue depth bound.
    pub max_queue_depth: usize,
    /// Micro-batching policy (shared by both lanes).
    pub batch: BatchPolicy,
}

impl Default for DispatcherConfig {
    fn default() -> Self {
        DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 4,
            max_queue_depth: 512,
            batch: BatchPolicy::default(),
        }
    }
}

/// One completed request, reported through [`Dispatcher::run_until`].
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub request: QueuedRequest,
    pub device: DeviceKind,
    /// When its batch started executing.
    pub start_s: f64,
    /// When its batch finished (= response time at the device).
    pub done_s: f64,
    /// Size of the batch it rode in.
    pub batch_size: usize,
}

/// Queue + capacity state for one device (internal to the dispatcher).
#[derive(Debug, Clone)]
struct Lane {
    queue: AdmissionQueue,
    tracker: CapacityTracker,
}

impl Lane {
    fn new(workers: usize, max_depth: usize) -> Self {
        Lane {
            queue: AdmissionQueue::new(max_depth),
            tracker: CapacityTracker::new(workers),
        }
    }
}

/// The two-lane edge/cloud dispatcher.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    edge: Lane,
    cloud: Lane,
    policy: BatchPolicy,
    stats: BatchStats,
}

impl Dispatcher {
    pub fn new(cfg: &DispatcherConfig) -> Self {
        Dispatcher {
            edge: Lane::new(cfg.edge_workers, cfg.max_queue_depth),
            cloud: Lane::new(cfg.cloud_workers, cfg.max_queue_depth),
            policy: cfg.batch,
            stats: BatchStats::default(),
        }
    }

    fn lane(&self, device: DeviceKind) -> &Lane {
        match device {
            DeviceKind::Edge => &self.edge,
            DeviceKind::Cloud => &self.cloud,
        }
    }

    fn lane_mut(&mut self, device: DeviceKind) -> &mut Lane {
        match device {
            DeviceKind::Edge => &mut self.edge,
            DeviceKind::Cloud => &mut self.cloud,
        }
    }

    /// Expected queueing delay on `device` for a request arriving now —
    /// the router adds this to each side of eq. 1.
    pub fn expected_wait_s(&self, device: DeviceKind, now_s: f64) -> f64 {
        let lane = self.lane(device);
        lane.tracker.expected_wait_s(now_s)
    }

    /// Admit a request to `device`'s queue (O(1)). The request's bucket
    /// is assigned here so queue and batcher always agree on it.
    pub fn submit(&mut self, device: DeviceKind, mut rq: QueuedRequest) -> Admission {
        rq.bucket = self.policy.bucket_of(rq.m_est);
        let lane = self.lane_mut(device);
        let admission = lane.queue.offer(rq);
        if admission.is_admitted() {
            lane.tracker.on_admit(rq.est_service_s);
        }
        admission
    }

    pub fn depth(&self, device: DeviceKind) -> usize {
        self.lane(device).queue.depth()
    }

    pub fn queue_stats(&self, device: DeviceKind) -> QueueStats {
        self.lane(device).queue.stats()
    }

    pub fn batch_stats(&self) -> BatchStats {
        self.stats
    }

    pub fn idle(&self) -> bool {
        self.edge.queue.is_empty() && self.cloud.queue.is_empty()
    }

    /// Run every batch (on both lanes) whose start time is ≤
    /// `horizon_s`; `on_complete` fires once per finished request.
    /// Drive with `horizon_s = next arrival time` while feeding
    /// arrivals, then once with `f64::INFINITY` to drain.
    pub fn run_until<E, F>(&mut self, horizon_s: f64, exec: &mut E, on_complete: &mut F)
    where
        E: BatchExecutor,
        F: FnMut(Completion),
    {
        drain_lane(
            DeviceKind::Edge,
            &mut self.edge,
            &self.policy,
            &mut self.stats,
            horizon_s,
            exec,
            on_complete,
        );
        drain_lane(
            DeviceKind::Cloud,
            &mut self.cloud,
            &self.policy,
            &mut self.stats,
            horizon_s,
            exec,
            on_complete,
        );
    }
}

fn drain_lane<E, F>(
    device: DeviceKind,
    lane: &mut Lane,
    policy: &BatchPolicy,
    stats: &mut BatchStats,
    horizon_s: f64,
    exec: &mut E,
    on_complete: &mut F,
) where
    E: BatchExecutor,
    F: FnMut(Completion),
{
    loop {
        let head_arrival = match lane.queue.peek() {
            None => return,
            Some(h) => h.arrival_s,
        };
        let (worker, free_s) = lane.tracker.earliest_free();
        let start_s = free_s.max(head_arrival);
        if start_s > horizon_s {
            return;
        }
        let batch = policy.form_batch(&mut lane.queue, start_s);
        debug_assert!(!batch.is_empty());
        let est_sum: f64 = batch.iter().map(|r| r.est_service_s).sum();
        let service_s = exec.execute(device, &batch, start_s).max(0.0);
        let done_s = start_s + service_s;
        lane.tracker.on_dispatch(worker, est_sum, done_s);
        stats.record(batch.len());
        let batch_size = batch.len();
        for request in batch {
            on_complete(Completion { request, device, start_s, done_s, batch_size });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fixed per-request time, batch = max + residual·rest.
    struct FixedExec {
        per_request_s: f64,
        residual: f64,
    }

    impl BatchExecutor for FixedExec {
        fn execute(&mut self, _d: DeviceKind, batch: &[QueuedRequest], _s: f64) -> f64 {
            let each = self.per_request_s;
            each + self.residual * each * (batch.len() - 1) as f64
        }
    }

    fn rq(id: u64, arrival_s: f64, m_est: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            payload: id as usize,
            n: 10,
            m_est,
            est_service_s: 0.1,
            arrival_s,
            bucket: 0, // overwritten by submit()
        }
    }

    fn collect_completions(
        disp: &mut Dispatcher,
        exec: &mut FixedExec,
        horizon_s: f64,
    ) -> Vec<Completion> {
        let mut out = Vec::new();
        disp.run_until(horizon_s, exec, &mut |c| out.push(c));
        out
    }

    #[test]
    fn lone_request_runs_immediately_without_batching_delay() {
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.2 };
        assert!(disp.submit(DeviceKind::Edge, rq(0, 1.0, 10.0)).is_admitted());
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 1);
        assert!((done[0].start_s - 1.0).abs() < 1e-12);
        assert!((done[0].done_s - 1.1).abs() < 1e-12);
        assert_eq!(done[0].batch_size, 1);
        assert!(disp.idle());
    }

    #[test]
    fn backlog_batches_and_amortises() {
        // One edge worker, four same-bucket requests arriving together:
        // they ride one batch and finish far sooner than serially.
        let cfg = DispatcherConfig { edge_workers: 1, ..Default::default() };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.2 };
        for i in 0..4 {
            disp.submit(DeviceKind::Edge, rq(i, 0.0, 10.0));
        }
        let done = collect_completions(&mut disp, &mut exec, f64::INFINITY);
        assert_eq!(done.len(), 4);
        assert_eq!(done[0].batch_size, 4);
        // 0.1 + 3·0.02 = 0.16 ≪ 0.4 serial.
        assert!((done[0].done_s - 0.16).abs() < 1e-9);
        assert!((disp.batch_stats().mean_batch_size() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn horizon_gates_dispatch() {
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = FixedExec { per_request_s: 0.1, residual: 0.0 };
        disp.submit(DeviceKind::Cloud, rq(0, 5.0, 10.0));
        assert!(collect_completions(&mut disp, &mut exec, 4.9).is_empty());
        let done = collect_completions(&mut disp, &mut exec, 5.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].device, DeviceKind::Cloud);
    }

    #[test]
    fn expected_wait_rises_with_backlog_and_falls_with_workers() {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 4,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        for i in 0..8 {
            disp.submit(DeviceKind::Edge, rq(i, 0.0, 10.0));
            disp.submit(DeviceKind::Cloud, rq(100 + i, 0.0, 10.0));
        }
        let we = disp.expected_wait_s(DeviceKind::Edge, 0.0);
        let wc = disp.expected_wait_s(DeviceKind::Cloud, 0.0);
        assert!((we - 0.8).abs() < 1e-12, "edge wait {we}");
        assert!((wc - 0.2).abs() < 1e-12, "cloud wait {wc}");
    }

    #[test]
    fn conservation_admitted_equals_completed() {
        let cfg = DispatcherConfig {
            max_queue_depth: 16,
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = FixedExec { per_request_s: 0.05, residual: 0.1 };
        let mut completed = 0usize;
        let mut rejected = 0usize;
        for i in 0..200u64 {
            let t = i as f64 * 0.01;
            disp.run_until(t, &mut exec, &mut |_c| completed += 1);
            let dev = if i % 3 == 0 { DeviceKind::Edge } else { DeviceKind::Cloud };
            if !disp.submit(dev, rq(i, t, (i % 40) as f64)).is_admitted() {
                rejected += 1;
            }
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut |_c| completed += 1);
        assert_eq!(completed + rejected, 200);
        let qs_e = disp.queue_stats(DeviceKind::Edge);
        let qs_c = disp.queue_stats(DeviceKind::Cloud);
        assert_eq!(qs_e.offered + qs_c.offered, 200);
        assert_eq!(qs_e.rejected + qs_c.rejected, rejected as u64);
        assert!(disp.idle());
    }
}
