//! Transmission-time model (the paper's T_tx).
//!
//! §II-B: "we model T_tx as being dominated by the connection's
//! round-trip time, and roughly [in]dependent of N and M" — tokens are
//! ~2-byte dictionary indices, so even a 64-token sentence is ≈128 bytes,
//! negligible at 100 Mbps next to a 40-300 ms RTT. We still model the
//! bandwidth term exactly (RTT + payload/bandwidth both ways) so the
//! approximation the *router* makes (RTT-only) is evaluated against a
//! ground truth that includes it, as in the paper.

use super::trace::RttTrace;

/// Payload accounting for an offloaded translation request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TxModel {
    /// Bytes per token on the wire (paper: "does not require more than 2
    /// bytes per word").
    pub bytes_per_token: f64,
    /// Fixed protocol overhead per message (headers etc.).
    pub overhead_bytes: f64,
    /// Symmetric link bandwidth, bits per second (paper: 100 Mbps).
    pub bandwidth_bps: f64,
}

impl Default for TxModel {
    fn default() -> Self {
        TxModel {
            bytes_per_token: 2.0,
            overhead_bytes: 64.0,
            bandwidth_bps: 100e6,
        }
    }
}

impl TxModel {
    /// Serialisation time of a payload of `tokens` tokens (one direction).
    pub fn payload_time(&self, tokens: usize) -> f64 {
        let bytes = self.bytes_per_token * tokens as f64 + self.overhead_bytes;
        bytes * 8.0 / self.bandwidth_bps
    }
}

/// The simulated edge↔cloud connection: an RTT trace plus the bandwidth
/// model. This is the *ground truth* the experiment harness charges an
/// offloaded request; the router's own T_tx estimator
/// ([`crate::predictor::ttx`]) only ever observes timestamped samples of
/// it, exactly like the real system.
#[derive(Debug, Clone)]
pub struct Network {
    trace: RttTrace,
    /// Payload-size transmission model.
    pub tx: TxModel,
}

impl Network {
    /// Network from an RTT trace plus a transmission model.
    pub fn new(trace: RttTrace, tx: TxModel) -> Self {
        Network { trace, tx }
    }

    /// Instantaneous RTT at simulation time `t`.
    pub fn rtt_at(&self, t: f64) -> f64 {
        self.trace.rtt_at(t)
    }

    /// Ground-truth transmission cost of offloading a request with `n`
    /// input tokens expecting `m` output tokens, starting at time `t`:
    /// one round trip + request payload up + response payload down.
    pub fn tx_time(&self, t: f64, n: usize, m: usize) -> f64 {
        self.trace.rtt_at(t)
            + self.tx.payload_time(n)
            + self.tx.payload_time(m)
    }

    /// The underlying RTT trace.
    pub fn trace(&self) -> &RttTrace {
        &self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_trace(rtt: f64) -> RttTrace {
        RttTrace { t: vec![0.0, 1e9], rtt: vec![rtt, rtt] }
    }

    #[test]
    fn payload_negligible_vs_rtt() {
        // The paper's premise: payload time ≪ RTT for NMT token payloads.
        let tx = TxModel::default();
        let payload = tx.payload_time(64);
        assert!(payload < 2e-5, "payload {payload}");
        let net = Network::new(flat_trace(0.040), tx);
        let total = net.tx_time(0.0, 64, 64);
        assert!((total - 0.040).abs() / 0.040 < 0.01, "total {total}");
    }

    #[test]
    fn tx_time_includes_both_directions() {
        let tx = TxModel { bytes_per_token: 1000.0, overhead_bytes: 0.0, bandwidth_bps: 8000.0 };
        // 1000 bytes/token at 1000 bytes/s -> 1 s per token each way.
        let net = Network::new(flat_trace(0.0), tx);
        let t = net.tx_time(0.0, 2, 3);
        assert!((t - 5.0).abs() < 1e-9, "t {t}");
    }

    #[test]
    fn rtt_follows_trace() {
        let tr = RttTrace { t: vec![0.0, 10.0], rtt: vec![0.1, 0.5] };
        let net = Network::new(tr, TxModel::default());
        assert!((net.rtt_at(5.0) - 0.1).abs() < 1e-12);
        assert!((net.rtt_at(9.99) - 0.1).abs() < 1e-12);
    }
}
