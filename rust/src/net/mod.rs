//! Network substrate: RTT connection profiles and the transmission-time
//! model.
//!
//! The paper evaluates with two *real* RIPE-Atlas round-trip-time traces
//! (meas 1437285, probe 6222, 2018-05-03: 3-7 p.m. = CP1, 7:30-12:30 a.m.
//! = CP2) replayed over simulation time, plus a constant symmetric
//! 100 Mbps bandwidth. We have no access to that archive, so
//! [`trace::TraceGenerator`] synthesises profiles with the same
//! qualitative structure (CP1 slower on average and burstier than CP2 —
//! Fig. 4), and [`trace::RttTrace`] replays them (ours or any CSV-loaded
//! real trace) identically to the paper's setup.

pub mod network;
pub mod trace;

pub use network::{Network, TxModel};
pub use trace::{ConnectionProfile, RttTrace, TraceGenerator};
