//! RTT trace generation, (de)serialisation and replay.
//!
//! Synthesis model: a mean-reverting Ornstein-Uhlenbeck process around
//! the profile's base RTT, plus exponentially-distributed congestion
//! spikes with geometric decay — the classic shape of consumer-uplink
//! RTT series (and what the RIPE-Atlas plot in the paper's Fig. 4 shows:
//! a noisy band with sporadic multi-hundred-ms excursions).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::util::Rng;
use crate::{Error, Result};

/// The two evaluation connection profiles of the paper (Fig. 4, Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConnectionProfile {
    /// "3-7 p.m." — slower on average, burstier (peak traffic hours).
    Cp1,
    /// "7:30-12:30 a.m." — faster, calmer.
    Cp2,
}

impl ConnectionProfile {
    /// Both paper connection profiles, in report order.
    pub const ALL: [ConnectionProfile; 2] =
        [ConnectionProfile::Cp1, ConnectionProfile::Cp2];

    /// Stable string id (`cp1` / `cp2`).
    pub fn id(&self) -> &'static str {
        match self {
            ConnectionProfile::Cp1 => "cp1",
            ConnectionProfile::Cp2 => "cp2",
        }
    }

    /// Parse an id produced by [`ConnectionProfile::id`].
    pub fn from_id(s: &str) -> Option<Self> {
        match s {
            "cp1" => Some(ConnectionProfile::Cp1),
            "cp2" => Some(ConnectionProfile::Cp2),
            _ => None,
        }
    }

    /// Synthesis parameters for this profile.
    pub fn params(&self) -> TraceParams {
        match self {
            // Afternoon/evening: congested consumer uplink.
            ConnectionProfile::Cp1 => TraceParams {
                base_rtt_s: 0.072,
                ou_sigma: 0.010,
                ou_theta: 0.05,
                spike_rate_per_s: 1.0 / 240.0, // one burst every ~4 min
                spike_mean_s: 0.220,
                spike_decay: 0.75,
                duration_s: 4.0 * 3600.0,
                sample_period_s: 10.0,
            },
            // Morning: quieter network.
            ConnectionProfile::Cp2 => TraceParams {
                base_rtt_s: 0.042,
                ou_sigma: 0.006,
                ou_theta: 0.08,
                spike_rate_per_s: 1.0 / 700.0,
                spike_mean_s: 0.120,
                spike_decay: 0.70,
                duration_s: 5.0 * 3600.0,
                sample_period_s: 10.0,
            },
        }
    }
}

/// OU + spike trace synthesis parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceParams {
    /// Long-run mean RTT (seconds).
    pub base_rtt_s: f64,
    /// OU noise scale per step.
    pub ou_sigma: f64,
    /// OU mean-reversion rate per step.
    pub ou_theta: f64,
    /// Poisson rate of congestion spikes (per second).
    pub spike_rate_per_s: f64,
    /// Mean spike magnitude (seconds, exponential).
    pub spike_mean_s: f64,
    /// Per-step geometric decay of active spike magnitude.
    pub spike_decay: f64,
    /// Total trace duration (seconds).
    pub duration_s: f64,
    /// Sampling period (seconds).
    pub sample_period_s: f64,
}

/// A time series of (timestamp, rtt) samples, replayable by time.
#[derive(Debug, Clone)]
pub struct RttTrace {
    /// Sample timestamps (seconds from trace start), strictly increasing.
    pub t: Vec<f64>,
    /// RTT at each timestamp (seconds).
    pub rtt: Vec<f64>,
}

impl RttTrace {
    /// Trace duration (seconds).
    pub fn duration(&self) -> f64 {
        self.t.last().copied().unwrap_or(0.0)
    }

    /// Number of RTT samples.
    pub fn len(&self) -> usize {
        self.t.len()
    }

    /// Is the trace empty?
    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// RTT at simulation time `time_s` (step interpolation: value of the
    /// latest sample at or before `time_s`; times wrap around the trace
    /// duration so any length of experiment can be replayed).
    pub fn rtt_at(&self, time_s: f64) -> f64 {
        assert!(!self.t.is_empty(), "empty trace");
        let dur = self.duration();
        let t = if dur > 0.0 { time_s.rem_euclid(dur) } else { 0.0 };
        // Binary search for the last sample <= t.
        match self
            .t
            .binary_search_by(|x| x.partial_cmp(&t).unwrap())
        {
            Ok(i) => self.rtt[i],
            Err(0) => self.rtt[0],
            Err(i) => self.rtt[i - 1],
        }
    }

    /// Mean RTT over the whole trace.
    pub fn mean(&self) -> f64 {
        if self.rtt.is_empty() {
            return f64::NAN;
        }
        self.rtt.iter().sum::<f64>() / self.rtt.len() as f64
    }

    /// Max RTT over the whole trace.
    pub fn max(&self) -> f64 {
        self.rtt.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Write as a 2-column CSV (`time_s,rtt_s`).
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "time_s,rtt_s")?;
        for (t, r) in self.t.iter().zip(&self.rtt) {
            writeln!(w, "{t},{r}")?;
        }
        Ok(())
    }

    /// Load from a 2-column CSV (header optional). Accepts real RIPE
    /// Atlas exports converted to `time_s,rtt_s`.
    pub fn load_csv(path: &Path) -> Result<RttTrace> {
        let f = std::fs::File::open(path)?;
        let reader = std::io::BufReader::new(f);
        let mut t = Vec::new();
        let mut rtt = Vec::new();
        for (lineno, line) in reader.lines().enumerate() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut cols = line.split(',');
            let a = cols.next().unwrap_or("");
            let b = cols.next().ok_or_else(|| {
                Error::Net(format!("{}:{}: expected 2 columns", path.display(), lineno + 1))
            })?;
            if lineno == 0 && a.parse::<f64>().is_err() {
                continue; // header
            }
            let at: f64 = a.parse().map_err(|_| {
                Error::Net(format!("{}:{}: bad time `{a}`", path.display(), lineno + 1))
            })?;
            let bt: f64 = b.trim().parse().map_err(|_| {
                Error::Net(format!("{}:{}: bad rtt `{b}`", path.display(), lineno + 1))
            })?;
            if let Some(&last) = t.last() {
                if at <= last {
                    return Err(Error::Net(format!(
                        "{}:{}: timestamps not increasing",
                        path.display(),
                        lineno + 1
                    )));
                }
            }
            t.push(at);
            rtt.push(bt.max(0.0));
        }
        if t.is_empty() {
            return Err(Error::Net(format!("{}: empty trace", path.display())));
        }
        Ok(RttTrace { t, rtt })
    }
}

/// Synthesises [`RttTrace`]s from [`TraceParams`].
#[derive(Debug)]
pub struct TraceGenerator {
    rng: Rng,
}

impl TraceGenerator {
    /// Deterministic generator from a seed.
    pub fn new(seed: u64) -> Self {
        TraceGenerator { rng: Rng::new(seed ^ 0x7EACE) }
    }

    /// Generate a named profile.
    pub fn profile(&mut self, p: ConnectionProfile) -> RttTrace {
        self.generate(&p.params())
    }

    /// Generate from explicit parameters.
    pub fn generate(&mut self, p: &TraceParams) -> RttTrace {
        let steps = (p.duration_s / p.sample_period_s).ceil() as usize;
        let mut t = Vec::with_capacity(steps);
        let mut rtt = Vec::with_capacity(steps);
        let mut ou = 0.0f64; // OU deviation from base
        let mut spike = 0.0f64; // active spike magnitude
        let spike_p = p.spike_rate_per_s * p.sample_period_s;
        for i in 0..steps {
            ou += p.ou_theta * (0.0 - ou) + p.ou_sigma * self.rng.normal();
            if self.rng.bool(spike_p.min(1.0)) {
                spike += self.rng.exponential(1.0 / p.spike_mean_s);
            }
            spike *= p.spike_decay;
            let sample = (p.base_rtt_s + ou + spike).max(0.001);
            t.push(i as f64 * p.sample_period_s);
            rtt.push(sample);
        }
        RttTrace { t, rtt }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_have_paper_ordering() {
        // CP1 must be slower on average than CP2 (paper: "the first
        // connection profile, which is slower on average").
        let mut g = TraceGenerator::new(1);
        let cp1 = g.profile(ConnectionProfile::Cp1);
        let cp2 = g.profile(ConnectionProfile::Cp2);
        assert!(
            cp1.mean() > 1.5 * cp2.mean(),
            "cp1 {} vs cp2 {}",
            cp1.mean(),
            cp2.mean()
        );
        // Both in a plausible WAN range.
        assert!((0.02..0.4).contains(&cp1.mean()));
        assert!((0.01..0.2).contains(&cp2.mean()));
        // Spikes exist: max well above mean.
        assert!(cp1.max() > 2.0 * cp1.mean());
    }

    #[test]
    fn replay_is_step_interpolated_and_wraps() {
        let tr = RttTrace { t: vec![0.0, 10.0, 20.0], rtt: vec![0.1, 0.2, 0.3] };
        assert_eq!(tr.rtt_at(0.0), 0.1);
        assert_eq!(tr.rtt_at(9.99), 0.1);
        assert_eq!(tr.rtt_at(10.0), 0.2);
        assert_eq!(tr.rtt_at(15.0), 0.2);
        assert_eq!(tr.rtt_at(20.0), 0.1); // wraps: 20 % 20 = 0
        assert_eq!(tr.rtt_at(25.0), 0.1); // 25 % 20 = 5
        assert_eq!(tr.rtt_at(39.9), 0.2); // 19.9
    }

    #[test]
    fn csv_roundtrip() {
        let mut g = TraceGenerator::new(2);
        let tr = g.profile(ConnectionProfile::Cp2);
        let dir = std::env::temp_dir().join("cnmt_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cp2.csv");
        tr.save_csv(&path).unwrap();
        let loaded = RttTrace::load_csv(&path).unwrap();
        assert_eq!(loaded.len(), tr.len());
        for (a, b) in tr.rtt.iter().zip(&loaded.rtt) {
            assert!((a - b).abs() < 1e-12);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_rejects_bad_input() {
        let dir = std::env::temp_dir().join("cnmt_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "time_s,rtt_s\n1.0,0.1\n0.5,0.2\n").unwrap();
        assert!(RttTrace::load_csv(&path).is_err()); // non-increasing
        std::fs::write(&path, "").unwrap();
        assert!(RttTrace::load_csv(&path).is_err()); // empty
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deterministic_generation() {
        let a = TraceGenerator::new(9).profile(ConnectionProfile::Cp1);
        let b = TraceGenerator::new(9).profile(ConnectionProfile::Cp1);
        assert_eq!(a.rtt, b.rtt);
    }

    #[test]
    fn duration_matches_params() {
        let mut g = TraceGenerator::new(3);
        let p = ConnectionProfile::Cp1.params();
        let tr = g.generate(&p);
        let expect = (p.duration_s / p.sample_period_s).ceil() as usize;
        assert_eq!(tr.len(), expect);
        assert!((tr.duration() - (expect - 1) as f64 * p.sample_period_s).abs() < 1e-9);
    }
}
