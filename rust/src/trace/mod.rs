//! Compact binary workload-trace format (`.ctr`): record a request
//! stream once, replay it bit-deterministically through the simulation
//! harness.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header (96 bytes):
//!   0..8    magic  b"CNMTRACE"
//!   8..10   version u16 (currently 1)
//!   10..12  flags   u16 (bit 0: TIMES_EXPLICIT)
//!   12..92  ten f64: edge plane (alpha_n, alpha_m, beta),
//!           cloud plane (alpha_n, alpha_m, beta),
//!           n2m gamma, n2m delta, mean_m, rtt_s
//!   92..96  crc32 of bytes 0..92
//! blocks, repeated:
//!   n_records u32 | payload_len u32 | payload | crc32(payload) u32
//! end marker:
//!   a block with n_records == 0 whose 8-byte payload is the u64
//!   total record count
//! ```
//!
//! Each record is a run of unsigned LEB128 varints. Arrival times are
//! quantized to integer microseconds and delta-encoded against the
//! previous record. In *derived* mode (the default) a record is just
//! `[delta_us, n, m]` and the service times are recomputed from the
//! header's cost planes; with [`FLAG_TIMES_EXPLICIT`] set each record
//! carries `[delta_us, n, m, t_edge_us, t_cloud_us, t_tx_us]`.
//!
//! Every structural defect — bad magic, unsupported version, CRC
//! mismatch, truncation, record-count mismatch — surfaces as a typed
//! [`Error::Trace`], never a panic.

use std::io::{Read, Write};

use crate::experiments::load::{CLOUD_PLANE, EDGE_PLANE, MEAN_N, N2M_DELTA, N2M_GAMMA, RTT_S};
use crate::predictor::{N2mRegressor, TexeModel};
use crate::sim::{Characterization, RequestTruth};
use crate::util::Rng;
use crate::{Error, Result};

/// File magic: the first eight bytes of every trace.
pub const TRACE_MAGIC: [u8; 8] = *b"CNMTRACE";

/// Format version this build reads and writes.
pub const TRACE_VERSION: u16 = 1;

/// Header flag bit 0: records carry explicit per-request service
/// times instead of deriving them from the header's cost planes.
pub const FLAG_TIMES_EXPLICIT: u16 = 1;

/// Fixed byte length of the trace header.
pub const HEADER_LEN: usize = 96;

/// Records per CRC-checked block.
pub const BLOCK_RECORDS: u32 = 4096;

/// Decoder sanity cap on a block's payload length (64 MiB).
const MAX_BLOCK_PAYLOAD: u32 = 1 << 26;

/// Decoder sanity cap on a block's record count.
const MAX_BLOCK_RECORDS: u32 = 1 << 22;

// ---------------------------------------------------------------------------
// CRC32 (IEEE, reflected — compatible with zlib.crc32)
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC32-IEEE (the zlib/`crc32` polynomial) over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Varints + microsecond quantization
// ---------------------------------------------------------------------------

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            return;
        }
        buf.push(b | 0x80);
    }
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| Error::Trace("varint runs past its block payload".into()))?;
        *pos += 1;
        if shift > 63 {
            return Err(Error::Trace("varint overflows u64".into()));
        }
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Quantize a duration in seconds to integer microseconds
/// (round-half-up). The inverse of [`us_to_s`]: for any count below
/// ~1e14 µs, `s_to_us(us_to_s(x)) == x`.
pub fn s_to_us(s: f64) -> u64 {
    (s * 1e6 + 0.5).floor() as u64
}

/// Integer microseconds back to seconds.
pub fn us_to_s(us: u64) -> f64 {
    us as f64 * 1e-6
}

// ---------------------------------------------------------------------------
// Header
// ---------------------------------------------------------------------------

/// The versioned, CRC-protected trace header: format metadata plus the
/// workload characterization (cost planes, n→m line, link RTT) needed
/// to derive service times and to build a [`Characterization`] for the
/// replay harness.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceHeader {
    /// Format version (must equal [`TRACE_VERSION`] to be readable).
    pub version: u16,
    /// Flag bits (see [`FLAG_TIMES_EXPLICIT`]).
    pub flags: u16,
    /// Edge-device T_exe plane `(alpha_n, alpha_m, beta)`.
    pub edge_plane: (f64, f64, f64),
    /// Cloud-device T_exe plane `(alpha_n, alpha_m, beta)`.
    pub cloud_plane: (f64, f64, f64),
    /// n→m regression slope.
    pub n2m_gamma: f64,
    /// n→m regression intercept.
    pub n2m_delta: f64,
    /// Mean output length over the whole trace (for the Naive router).
    pub mean_m: f64,
    /// Link round-trip time in seconds.
    pub rtt_s: f64,
}

impl TraceHeader {
    /// Whether records carry explicit service times.
    pub fn times_explicit(&self) -> bool {
        self.flags & FLAG_TIMES_EXPLICIT != 0
    }

    /// Build the simulation-harness [`Characterization`] this trace
    /// describes (warm cost models, no fit diagnostics).
    pub fn characterization(&self) -> Characterization {
        Characterization {
            texe_edge: TexeModel::from_coeffs(
                self.edge_plane.0,
                self.edge_plane.1,
                self.edge_plane.2,
            ),
            texe_cloud: TexeModel::from_coeffs(
                self.cloud_plane.0,
                self.cloud_plane.1,
                self.cloud_plane.2,
            ),
            n2m: N2mRegressor::from_coeffs(self.n2m_gamma, self.n2m_delta),
            mean_m: self.mean_m,
        }
    }

    fn encode(&self) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[0..8].copy_from_slice(&TRACE_MAGIC);
        b[8..10].copy_from_slice(&self.version.to_le_bytes());
        b[10..12].copy_from_slice(&self.flags.to_le_bytes());
        let fields = [
            self.edge_plane.0,
            self.edge_plane.1,
            self.edge_plane.2,
            self.cloud_plane.0,
            self.cloud_plane.1,
            self.cloud_plane.2,
            self.n2m_gamma,
            self.n2m_delta,
            self.mean_m,
            self.rtt_s,
        ];
        for (i, f) in fields.iter().enumerate() {
            b[12 + 8 * i..20 + 8 * i].copy_from_slice(&f.to_le_bytes());
        }
        let crc = crc32(&b[..92]);
        b[92..96].copy_from_slice(&crc.to_le_bytes());
        b
    }

    fn decode(b: &[u8; HEADER_LEN]) -> Result<TraceHeader> {
        if b[0..8] != TRACE_MAGIC {
            return Err(Error::Trace("not a cnmt trace (bad magic)".into()));
        }
        let stored = u32::from_le_bytes([b[92], b[93], b[94], b[95]]);
        if crc32(&b[..92]) != stored {
            return Err(Error::Trace("header crc mismatch (corrupted trace)".into()));
        }
        let version = u16::from_le_bytes([b[8], b[9]]);
        if version != TRACE_VERSION {
            return Err(Error::Trace(format!(
                "unsupported trace version {version} (this build reads version {TRACE_VERSION})"
            )));
        }
        let flags = u16::from_le_bytes([b[10], b[11]]);
        if flags & !FLAG_TIMES_EXPLICIT != 0 {
            return Err(Error::Trace(format!("unknown trace flags {flags:#06x}")));
        }
        let mut fields = [0.0f64; 10];
        for (i, f) in fields.iter_mut().enumerate() {
            let mut raw = [0u8; 8];
            raw.copy_from_slice(&b[12 + 8 * i..20 + 8 * i]);
            *f = f64::from_le_bytes(raw);
        }
        Ok(TraceHeader {
            version,
            flags,
            edge_plane: (fields[0], fields[1], fields[2]),
            cloud_plane: (fields[3], fields[4], fields[5]),
            n2m_gamma: fields[6],
            n2m_delta: fields[7],
            mean_m: fields[8],
            rtt_s: fields[9],
        })
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Streaming trace encoder: push [`RequestTruth`] records in arrival
/// order, blocks are CRC-sealed and flushed every [`BLOCK_RECORDS`]
/// records, and [`TraceWriter::finish`] appends the end marker.
pub struct TraceWriter<W: Write> {
    w: W,
    explicit: bool,
    texe_edge: TexeModel,
    texe_cloud: TexeModel,
    rtt_us: u64,
    buf: Vec<u8>,
    n_in_block: u32,
    total: u64,
    last_us: u64,
}

impl<W: Write> TraceWriter<W> {
    /// Write the header and return a writer for the record stream.
    pub fn create(mut w: W, header: &TraceHeader) -> Result<Self> {
        if header.version != TRACE_VERSION {
            return Err(Error::Trace(format!(
                "cannot write trace version {} (this build writes version {TRACE_VERSION})",
                header.version
            )));
        }
        w.write_all(&header.encode())?;
        Ok(TraceWriter {
            w,
            explicit: header.times_explicit(),
            texe_edge: TexeModel::from_coeffs(
                header.edge_plane.0,
                header.edge_plane.1,
                header.edge_plane.2,
            ),
            texe_cloud: TexeModel::from_coeffs(
                header.cloud_plane.0,
                header.cloud_plane.1,
                header.cloud_plane.2,
            ),
            rtt_us: s_to_us(header.rtt_s),
            buf: Vec::with_capacity(BLOCK_RECORDS as usize * 8),
            n_in_block: 0,
            total: 0,
            last_us: 0,
        })
    }

    /// Append one record. Records must arrive sorted by `arrival_s`;
    /// in derived mode the record's times must match the header's
    /// planes exactly after µs quantization (use
    /// [`FLAG_TIMES_EXPLICIT`] for workloads with execution noise).
    pub fn push(&mut self, truth: &RequestTruth) -> Result<()> {
        let arrival_us = s_to_us(truth.arrival_s);
        let delta = arrival_us.checked_sub(self.last_us).ok_or_else(|| {
            Error::Trace("records must be pushed in non-decreasing arrival order".into())
        })?;
        self.last_us = arrival_us;
        put_varint(&mut self.buf, delta);
        put_varint(&mut self.buf, truth.n as u64);
        put_varint(&mut self.buf, truth.m_real as u64);
        if self.explicit {
            put_varint(&mut self.buf, s_to_us(truth.t_edge));
            put_varint(&mut self.buf, s_to_us(truth.t_cloud));
            put_varint(&mut self.buf, s_to_us(truth.t_tx));
        } else {
            let e_us = s_to_us(self.texe_edge.estimate(truth.n, truth.m_real as f64));
            let c_us = s_to_us(self.texe_cloud.estimate(truth.n, truth.m_real as f64));
            if s_to_us(truth.t_edge) != e_us
                || s_to_us(truth.t_cloud) != c_us
                || s_to_us(truth.t_tx) != self.rtt_us
            {
                return Err(Error::Trace(
                    "derived-mode record's times do not match the header planes \
                     (set FLAG_TIMES_EXPLICIT to store per-record times)"
                        .into(),
                ));
            }
        }
        self.n_in_block += 1;
        self.total += 1;
        if self.n_in_block >= BLOCK_RECORDS {
            self.flush_block()?;
        }
        Ok(())
    }

    fn flush_block(&mut self) -> Result<()> {
        if self.n_in_block == 0 {
            return Ok(());
        }
        self.w.write_all(&self.n_in_block.to_le_bytes())?;
        self.w.write_all(&(self.buf.len() as u32).to_le_bytes())?;
        self.w.write_all(&self.buf)?;
        self.w.write_all(&crc32(&self.buf).to_le_bytes())?;
        self.buf.clear();
        self.n_in_block = 0;
        Ok(())
    }

    /// Seal the final block, append the end marker (record count), and
    /// return the underlying writer.
    pub fn finish(mut self) -> Result<W> {
        self.flush_block()?;
        let payload = self.total.to_le_bytes();
        self.w.write_all(&0u32.to_le_bytes())?;
        self.w.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.w.write_all(&payload)?;
        self.w.write_all(&crc32(&payload).to_le_bytes())?;
        self.w.flush()?;
        Ok(self.w)
    }
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// Streaming trace decoder: an `Iterator` over
/// `Result<RequestTruth>` that validates the header up front, each
/// block's CRC as it is reached, and the end marker's record count.
pub struct TraceReader<R: Read> {
    r: R,
    header: TraceHeader,
    explicit: bool,
    texe_edge: TexeModel,
    texe_cloud: TexeModel,
    rtt_us: u64,
    buf: Vec<u8>,
    pos: usize,
    left_in_block: u32,
    cum_us: u64,
    seen: u64,
    done: bool,
}

impl<R: Read> TraceReader<R> {
    /// Read and validate the header, returning a record iterator.
    pub fn open(mut r: R) -> Result<Self> {
        let mut hb = [0u8; HEADER_LEN];
        r.read_exact(&mut hb)
            .map_err(|_| Error::Trace("truncated trace: incomplete header".into()))?;
        let header = TraceHeader::decode(&hb)?;
        Ok(TraceReader {
            r,
            explicit: header.times_explicit(),
            texe_edge: TexeModel::from_coeffs(
                header.edge_plane.0,
                header.edge_plane.1,
                header.edge_plane.2,
            ),
            texe_cloud: TexeModel::from_coeffs(
                header.cloud_plane.0,
                header.cloud_plane.1,
                header.cloud_plane.2,
            ),
            rtt_us: s_to_us(header.rtt_s),
            header,
            buf: Vec::new(),
            pos: 0,
            left_in_block: 0,
            cum_us: 0,
            seen: 0,
            done: false,
        })
    }

    /// The validated header.
    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    fn read_u32(&mut self, what: &str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r
            .read_exact(&mut b)
            .map_err(|_| Error::Trace(format!("truncated trace: incomplete {what}")))?;
        Ok(u32::from_le_bytes(b))
    }

    /// Load the next block into `buf`. Returns `false` when the end
    /// marker was reached (and its record count verified).
    fn next_block(&mut self) -> Result<bool> {
        let n = self.read_u32("block length prefix")?;
        let len = self.read_u32("block length prefix")?;
        if len > MAX_BLOCK_PAYLOAD {
            return Err(Error::Trace(format!(
                "block payload length {len} exceeds the format bound {MAX_BLOCK_PAYLOAD}"
            )));
        }
        self.buf.resize(len as usize, 0);
        self.r
            .read_exact(&mut self.buf)
            .map_err(|_| Error::Trace("truncated trace: incomplete block payload".into()))?;
        let stored = self.read_u32("block crc")?;
        if crc32(&self.buf) != stored {
            return Err(Error::Trace("block crc mismatch (corrupted trace)".into()));
        }
        if n == 0 {
            if self.buf.len() != 8 {
                return Err(Error::Trace("malformed end marker".into()));
            }
            let mut raw = [0u8; 8];
            raw.copy_from_slice(&self.buf);
            let total = u64::from_le_bytes(raw);
            if total != self.seen {
                return Err(Error::Trace(format!(
                    "record count mismatch: end marker says {total}, stream held {}",
                    self.seen
                )));
            }
            return Ok(false);
        }
        if n > MAX_BLOCK_RECORDS {
            return Err(Error::Trace(format!(
                "block record count {n} exceeds the format bound {MAX_BLOCK_RECORDS}"
            )));
        }
        self.left_in_block = n;
        self.pos = 0;
        Ok(true)
    }

    fn decode_one(&mut self) -> Result<RequestTruth> {
        let delta = get_varint(&self.buf, &mut self.pos)?;
        let n = get_varint(&self.buf, &mut self.pos)? as usize;
        let m = get_varint(&self.buf, &mut self.pos)? as usize;
        if n == 0 || m == 0 {
            return Err(Error::Trace("record has a zero-length sentence".into()));
        }
        self.cum_us = self
            .cum_us
            .checked_add(delta)
            .ok_or_else(|| Error::Trace("arrival clock overflows u64 microseconds".into()))?;
        let (e_us, c_us, tx_us) = if self.explicit {
            (
                get_varint(&self.buf, &mut self.pos)?,
                get_varint(&self.buf, &mut self.pos)?,
                get_varint(&self.buf, &mut self.pos)?,
            )
        } else {
            (
                s_to_us(self.texe_edge.estimate(n, m as f64)),
                s_to_us(self.texe_cloud.estimate(n, m as f64)),
                self.rtt_us,
            )
        };
        self.left_in_block -= 1;
        if self.left_in_block == 0 && self.pos != self.buf.len() {
            return Err(Error::Trace("block payload has trailing bytes".into()));
        }
        self.seen += 1;
        Ok(RequestTruth {
            n,
            m_real: m,
            arrival_s: us_to_s(self.cum_us),
            t_edge: us_to_s(e_us),
            t_cloud: us_to_s(c_us),
            t_tx: us_to_s(tx_us),
            rtt: us_to_s(tx_us),
        })
    }
}

impl<R: Read> Iterator for TraceReader<R> {
    type Item = Result<RequestTruth>;

    fn next(&mut self) -> Option<Result<RequestTruth>> {
        if self.done {
            return None;
        }
        if self.left_in_block == 0 {
            match self.next_block() {
                Ok(true) => {}
                Ok(false) => {
                    self.done = true;
                    return None;
                }
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            }
        }
        match self.decode_one() {
            Ok(t) => Some(Ok(t)),
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Summary (for `cnmt trace info`)
// ---------------------------------------------------------------------------

/// Aggregate statistics of a trace, computed in one streaming pass.
#[derive(Debug, Clone, Copy)]
pub struct TraceSummary {
    /// Format version from the header.
    pub version: u16,
    /// Whether records carry explicit service times.
    pub times_explicit: bool,
    /// Total record count (verified against the end marker).
    pub records: u64,
    /// Arrival time of the last record (the first arrives near 0).
    pub duration_s: f64,
    /// Empirical offered load, records / duration.
    pub offered_rps: f64,
    /// Mean input length over the trace.
    pub mean_n: f64,
    /// Mean output length over the trace.
    pub mean_m: f64,
}

/// Walk a whole trace, validating every block CRC and the end marker,
/// and return its summary.
pub fn summarize<R: Read>(r: R) -> Result<TraceSummary> {
    let mut reader = TraceReader::open(r)?;
    let header = *reader.header();
    let mut records = 0u64;
    let mut last_arrival_s = 0.0f64;
    let mut sum_n = 0u64;
    let mut sum_m = 0u64;
    for rec in &mut reader {
        let t = rec?;
        records += 1;
        last_arrival_s = t.arrival_s;
        sum_n += t.n as u64;
        sum_m += t.m_real as u64;
    }
    let denom = records.max(1) as f64;
    Ok(TraceSummary {
        version: header.version,
        times_explicit: header.times_explicit(),
        records,
        duration_s: last_arrival_s,
        offered_rps: if last_arrival_s > 0.0 { records as f64 / last_arrival_s } else { 0.0 },
        mean_n: sum_n as f64 / denom,
        mean_m: sum_m as f64 / denom,
    })
}

// ---------------------------------------------------------------------------
// Synthetic scenario generator (µs-quantized, trace-native)
// ---------------------------------------------------------------------------

/// Output-length noise std dev of the synthetic scenario (tokens).
const SYNTH_M_NOISE_STD: f64 = 2.0;

/// Sentence-length cap of the synthetic scenario (tokens).
const SYNTH_N_MAX: usize = 62;

/// Parameters of the trace-native synthetic scenario used by
/// `cnmt trace record` and the checked-in CI traces.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SynthSpec {
    /// Master RNG seed.
    pub seed: u64,
    /// Number of requests to generate.
    pub requests: usize,
    /// Offered load in requests per second.
    pub offered_rps: f64,
    /// Execution-time noise std dev; `0.0` selects derived mode
    /// (3 varints per record), anything larger selects explicit mode.
    pub exec_noise_std: f64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec { seed: 20_220_315, requests: 100_000, offered_rps: 96.0, exec_noise_std: 0.0 }
    }
}

/// Lazy generator of the synthetic scenario: a xoshiro256** stream of
/// Poisson arrivals with correlated input/output lengths, every time
/// quantized to integer microseconds so that the generated stream,
/// the encoded trace, and the decoded replay are bit-identical.
pub struct SynthTrace {
    rng: Rng,
    remaining: usize,
    cum_us: u64,
    offered_rps: f64,
    noise_std: f64,
    texe_edge: TexeModel,
    texe_cloud: TexeModel,
    rtt_us: u64,
}

impl SynthTrace {
    /// Start the generator for `spec`.
    pub fn new(spec: &SynthSpec) -> Self {
        SynthTrace {
            rng: Rng::new(spec.seed),
            remaining: spec.requests,
            cum_us: 0,
            offered_rps: spec.offered_rps,
            noise_std: spec.exec_noise_std,
            texe_edge: TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2),
            texe_cloud: TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2),
            rtt_us: s_to_us(RTT_S),
        }
    }
}

impl Iterator for SynthTrace {
    type Item = RequestTruth;

    fn next(&mut self) -> Option<RequestTruth> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let dt = self.rng.exponential(self.offered_rps);
        let n = 1 + (self.rng.exponential(1.0 / MEAN_N) as usize).min(SYNTH_N_MAX - 1);
        let m_mean = N2M_GAMMA * n as f64 + N2M_DELTA;
        let m = (m_mean + self.rng.normal_ms(0.0, SYNTH_M_NOISE_STD))
            .round()
            .clamp(1.0, SYNTH_N_MAX as f64) as usize;
        let (noise_e, noise_c) = if self.noise_std > 0.0 {
            (
                (1.0 + self.rng.normal_ms(0.0, self.noise_std)).max(0.2),
                (1.0 + self.rng.normal_ms(0.0, self.noise_std)).max(0.2),
            )
        } else {
            (1.0, 1.0)
        };
        self.cum_us += s_to_us(dt);
        let e_us = s_to_us(self.texe_edge.estimate(n, m as f64) * noise_e);
        let c_us = s_to_us(self.texe_cloud.estimate(n, m as f64) * noise_c);
        Some(RequestTruth {
            n,
            m_real: m,
            arrival_s: us_to_s(self.cum_us),
            t_edge: us_to_s(e_us),
            t_cloud: us_to_s(c_us),
            t_tx: us_to_s(self.rtt_us),
            rtt: us_to_s(self.rtt_us),
        })
    }
}

/// Build the header for `spec`: a characterization prepass runs the
/// full generator once to compute the trace-wide `mean_m` (the replay
/// harness's Naive router needs it), so record+header stay a pure
/// function of the spec.
pub fn synth_header(spec: &SynthSpec) -> TraceHeader {
    let mut sum_m = 0u64;
    for t in SynthTrace::new(spec) {
        sum_m += t.m_real as u64;
    }
    TraceHeader {
        version: TRACE_VERSION,
        flags: if spec.exec_noise_std > 0.0 { FLAG_TIMES_EXPLICIT } else { 0 },
        edge_plane: EDGE_PLANE,
        cloud_plane: CLOUD_PLANE,
        n2m_gamma: N2M_GAMMA,
        n2m_delta: N2M_DELTA,
        mean_m: sum_m as f64 / spec.requests.max(1) as f64,
        rtt_s: RTT_S,
    }
}

/// Record the synthetic scenario for `spec` into `w` (header prepass
/// plus a second streaming generation pass; peak memory is one block).
pub fn record_synth<W: Write>(spec: &SynthSpec, w: W) -> Result<(TraceHeader, W)> {
    let header = synth_header(spec);
    let mut writer = TraceWriter::create(w, &header)?;
    for t in SynthTrace::new(spec) {
        writer.push(&t)?;
    }
    let w = writer.finish()?;
    Ok((header, w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn small_spec() -> SynthSpec {
        SynthSpec { seed: 7, requests: 300, offered_rps: 80.0, exec_noise_std: 0.0 }
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn varint_round_trip() {
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        let mut buf = Vec::new();
        for &v in &vals {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn quantization_round_trips() {
        for us in [0u64, 1, 41_999, 42_000, 1_000_000_007, 123_456_789_012_345] {
            assert_eq!(s_to_us(us_to_s(us)), us);
        }
    }

    #[test]
    fn header_round_trip_and_corruption() {
        let header = synth_header(&small_spec());
        let bytes = header.encode();
        assert_eq!(TraceHeader::decode(&bytes).unwrap(), header);

        let mut bad = bytes;
        bad[20] ^= 0xFF;
        let err = TraceHeader::decode(&bad).unwrap_err();
        assert!(matches!(err, Error::Trace(ref m) if m.contains("crc")), "{err}");

        let mut wrong_magic = bytes;
        wrong_magic[0] = b'X';
        let err = TraceHeader::decode(&wrong_magic).unwrap_err();
        assert!(matches!(err, Error::Trace(ref m) if m.contains("magic")), "{err}");
    }

    #[test]
    fn synth_record_replay_is_bit_identical() {
        let spec = small_spec();
        let (_, bytes) = record_synth(&spec, Vec::new()).unwrap();
        let decoded: Vec<RequestTruth> = TraceReader::open(Cursor::new(&bytes))
            .unwrap()
            .collect::<Result<Vec<_>>>()
            .unwrap();
        let live: Vec<RequestTruth> = SynthTrace::new(&spec).collect();
        assert_eq!(decoded.len(), live.len());
        for (d, l) in decoded.iter().zip(&live) {
            assert_eq!(d.n, l.n);
            assert_eq!(d.m_real, l.m_real);
            assert_eq!(d.arrival_s.to_bits(), l.arrival_s.to_bits());
            assert_eq!(d.t_edge.to_bits(), l.t_edge.to_bits());
            assert_eq!(d.t_cloud.to_bits(), l.t_cloud.to_bits());
            assert_eq!(d.t_tx.to_bits(), l.t_tx.to_bits());
            assert_eq!(d.rtt.to_bits(), l.rtt.to_bits());
        }
    }

    #[test]
    fn truncated_and_corrupted_blocks_fail_closed() {
        let (_, bytes) = record_synth(&small_spec(), Vec::new()).unwrap();

        // Chop the end marker off: the reader must report truncation,
        // not silently yield a short stream.
        let cut = &bytes[..bytes.len() - 10];
        let err = TraceReader::open(Cursor::new(cut))
            .unwrap()
            .collect::<Result<Vec<_>>>()
            .unwrap_err();
        assert!(matches!(err, Error::Trace(ref m) if m.contains("truncated")), "{err}");

        // Flip one payload byte: the block CRC must catch it.
        let mut corrupt = bytes.clone();
        corrupt[HEADER_LEN + 16] ^= 0x01;
        let err = TraceReader::open(Cursor::new(&corrupt))
            .unwrap()
            .collect::<Result<Vec<_>>>()
            .unwrap_err();
        assert!(matches!(err, Error::Trace(ref m) if m.contains("crc")), "{err}");
    }

    #[test]
    fn summarize_counts_match() {
        let spec = small_spec();
        let (header, bytes) = record_synth(&spec, Vec::new()).unwrap();
        let s = summarize(Cursor::new(&bytes)).unwrap();
        assert_eq!(s.records, spec.requests as u64);
        assert_eq!(s.version, TRACE_VERSION);
        assert!(!s.times_explicit);
        assert!((s.mean_m - header.mean_m).abs() < 1e-12);
        assert!(s.duration_s > 0.0);
    }
}
