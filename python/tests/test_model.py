"""L2 model tests: shapes, decode-loop semantics, registry contract.

These validate the encode/decode-step functions that get AOT-lowered —
static shapes, state threading, mask behaviour — plus full greedy decode
loops run in python that mirror exactly what the rust driver does.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _tokens(ids):
    """Pad a python list of ids to [1, N_MAX] and return (tokens, length)."""
    t = np.full((1, M.N_MAX), M.PAD_ID, np.int32)
    t[0, : len(ids)] = ids
    return jnp.asarray(t), jnp.asarray(len(ids), jnp.int32)


@pytest.fixture(scope="module")
def specs():
    return {s.name: s for s in M.make_specs()}


@pytest.fixture(scope="module")
def params(specs):
    return {
        name: spec.init(jax.random.PRNGKey(7)) for name, spec in specs.items()
    }


def _decode_inputs_initial(spec, enc_out, length):
    """Mirror of the rust driver's first-step decode input assembly."""
    args = []
    for d in spec.decode_inputs:
        if d.kind == "enc":
            args.append(enc_out[d.idx])
        elif d.kind == "length":
            args.append(length)
        elif d.kind == "token":
            args.append(jnp.asarray([M.BOS_ID], jnp.int32))
        elif d.kind == "state":
            if d.init["kind"] == "enc":
                args.append(enc_out[d.init["idx"]])
            else:
                dt = jnp.int32 if d.init["dtype"] == "i32" else jnp.float32
                args.append(jnp.zeros(tuple(d.init["shape"]), dt))
    return args


def _greedy_decode(spec, p, src_ids, steps):
    """Run encode + `steps` decode steps, returning emitted tokens."""
    tokens, length = _tokens(src_ids)
    enc_out = spec.encode(p, tokens, length)
    if not isinstance(enc_out, tuple):
        enc_out = (enc_out,)
    args = _decode_inputs_initial(spec, enc_out, length)
    state_pos = [i for i, d in enumerate(spec.decode_inputs)
                 if d.kind == "state"]
    token_pos = next(i for i, d in enumerate(spec.decode_inputs)
                     if d.kind == "token")
    out_tokens = []
    for _ in range(steps):
        outs = spec.decode_step(p, *args)
        nxt, states = outs[0], outs[1:]
        out_tokens.append(int(nxt[0]))
        assert len(states) == len(state_pos), (
            "decode_step must return exactly its state tensors")
        for slot, s in zip(state_pos, states):
            args[slot] = s
        args[token_pos] = nxt
    return out_tokens


class TestShapes:
    @pytest.mark.parametrize("name", [
        "bilstm_de_en", "gru_fr_en", "transformer_en_zh"])
    def test_encode_shapes_match_eval_shape(self, specs, params, name):
        spec, p = specs[name], params[name]
        tokens, length = _tokens([5, 6, 7])
        got = spec.encode(p, tokens, length)
        if not isinstance(got, tuple):
            got = (got,)
        want = jax.eval_shape(spec.encode, p, *M.encode_example_args())
        if not isinstance(want, tuple):
            want = (want,)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            assert g.shape == w.shape, (name, g.shape, w.shape)
            assert g.dtype == w.dtype

    @pytest.mark.parametrize("name", [
        "bilstm_de_en", "gru_fr_en", "transformer_en_zh"])
    def test_decode_example_args_accepted(self, specs, params, name):
        """decode_step must trace with exactly the manifest's arg shapes."""
        spec, p = specs[name], params[name]
        args = [jnp.zeros(a.shape, a.dtype) for a in M.decode_example_args(spec)]
        outs = spec.decode_step(p, *args)
        assert outs[0].shape == (1,)
        assert outs[0].dtype == jnp.int32
        assert len(outs) == 1 + spec.n_state


class TestDecodeLoop:
    @pytest.mark.parametrize("name", [
        "bilstm_de_en", "gru_fr_en", "transformer_en_zh"])
    def test_greedy_decode_deterministic(self, specs, params, name):
        spec, p = specs[name], params[name]
        a = _greedy_decode(spec, p, [10, 11, 12, 13], steps=5)
        b = _greedy_decode(spec, p, [10, 11, 12, 13], steps=5)
        assert a == b
        assert all(0 <= t < M.VOCAB for t in a)

    @pytest.mark.parametrize("name", [
        "bilstm_de_en", "gru_fr_en", "transformer_en_zh"])
    def test_output_depends_on_input(self, specs, params, name):
        """Different source sentences should (generically) decode
        differently — guards against the context being dropped."""
        spec, p = specs[name], params[name]
        a = _greedy_decode(spec, p, [10, 11, 12, 13], steps=6)
        b = _greedy_decode(spec, p, [900, 901, 902, 903, 904, 905], steps=6)
        assert a != b

    @pytest.mark.parametrize("name", [
        "bilstm_de_en", "gru_fr_en", "transformer_en_zh"])
    def test_padding_invariance(self, specs, params, name):
        """Tokens past `length` must not affect the decode — this is the
        masking contract the rust driver relies on when it pads."""
        spec, p = specs[name], params[name]
        src = [42, 43, 44]
        tokens_a, length = _tokens(src)
        tokens_b = tokens_a.at[0, 10:20].set(999)  # garbage in padding
        enc_a = spec.encode(p, tokens_a, length)
        enc_b = spec.encode(p, tokens_b, length)
        if not isinstance(enc_a, tuple):
            enc_a, enc_b = (enc_a,), (enc_b,)
        for ea, eb in zip(enc_a, enc_b):
            if ea.dtype in (jnp.float32, jnp.bfloat16):
                # BiLSTM enc_attn rows in the padded region differ (they are
                # masked at attention time); compare only valid rows when the
                # first axis is the sequence axis.
                if ea.ndim >= 2 and ea.shape[-2] == M.N_MAX:
                    ea = ea[..., : len(src), :]
                    eb = eb[..., : len(src), :]
                elif ea.ndim >= 2 and ea.shape[0] == M.N_MAX:
                    ea, eb = ea[: len(src)], eb[: len(src)]
                np.testing.assert_allclose(
                    np.asarray(ea), np.asarray(eb), rtol=1e-5, atol=1e-6)

    def test_transformer_pos_advances(self, specs, params):
        spec, p = specs["transformer_en_zh"], params["transformer_en_zh"]
        tokens, length = _tokens([9, 8, 7])
        enc = spec.encode(p, tokens, length)
        args = _decode_inputs_initial(spec, enc, length)
        outs = spec.decode_step(p, *args)
        # state order: cache_k, cache_v, pos
        assert int(outs[3]) == 1
        ck = np.asarray(outs[1])
        # cache slot 0 must be written, slots >0 still zero
        assert np.abs(ck[:, 0, :]).sum() > 0
        assert np.abs(ck[:, 1:, :]).sum() == 0


class TestRegistry:
    def test_three_specs_in_table1_order(self):
        names = [s.name for s in M.make_specs()]
        assert names == ["bilstm_de_en", "gru_fr_en", "transformer_en_zh"]

    def test_spec_by_name_roundtrip(self):
        for s in M.make_specs():
            assert M.spec_by_name(s.name).name == s.name
        with pytest.raises(KeyError):
            M.spec_by_name("nope")

    def test_decode_inputs_have_single_token_slot(self):
        for s in M.make_specs():
            kinds = [d.kind for d in s.decode_inputs]
            assert kinds.count("token") == 1
            assert kinds[-1] == "token", "token is last by convention"
            # state indices are dense 0..n_state-1
            idxs = sorted(d.idx for d in s.decode_inputs if d.kind == "state")
            assert idxs == list(range(s.n_state))

    def test_state_inits_well_formed(self):
        for s in M.make_specs():
            for d in s.decode_inputs:
                if d.kind == "state":
                    assert d.init["kind"] in ("enc", "zeros")
                    if d.init["kind"] == "zeros":
                        assert d.init["dtype"] in ("f32", "i32")
