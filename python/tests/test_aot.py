"""AOT path tests: HLO text well-formedness, manifest consistency, weight
blob layout — the python half of the artifact contract the rust runtime
relies on (rust/src/runtime/manifest.rs is the other half).

Lowering all three models takes ~minutes, so these tests lower ONE small
model (the GRU) from scratch and, when `make artifacts` has already run,
validate the shipped artifacts directory too.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def gru_export(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    spec = M.spec_by_name("gru_fr_en")
    entry = aot.export_model(spec, out)
    return out, entry


class TestExport:
    def test_hlo_text_is_parseable_hlo(self, gru_export):
        out, entry = gru_export
        for key in ("encode_hlo", "decode_hlo"):
            path = os.path.join(out, entry[key])
            text = open(path).read()
            assert text.startswith("HloModule"), f"{key} missing HloModule header"
            assert "ENTRY" in text
            # Parameters present (weights passed as params, not constants).
            assert "parameter(0)" in text

    def test_weights_blob_matches_manifest(self, gru_export):
        out, entry = gru_export
        blob = open(os.path.join(out, entry["weights_bin"]), "rb").read()
        total = sum(p["nbytes"] for p in entry["params"])
        assert len(blob) == total
        # Offsets dense and ordered.
        expect = 0
        for p in entry["params"]:
            assert p["offset"] == expect
            shape_elems = int(np.prod(p["shape"])) if p["shape"] else 1
            assert shape_elems * 4 == p["nbytes"]
            expect += p["nbytes"]

    def test_params_sorted_by_name(self, gru_export):
        _, entry = gru_export
        names = [p["name"] for p in entry["params"]]
        assert names == sorted(names)

    def test_sha256_matches(self, gru_export):
        out, entry = gru_export
        import hashlib
        blob = open(os.path.join(out, entry["weights_bin"]), "rb").read()
        assert hashlib.sha256(blob).hexdigest() == entry["weights_sha256"]

    def test_decode_wiring_round_trips_registry(self, gru_export):
        _, entry = gru_export
        spec = M.spec_by_name("gru_fr_en")
        assert entry["decode_inputs"] == [d.to_json() for d in spec.decode_inputs]
        assert entry["n_state"] == spec.n_state

    def test_export_is_deterministic(self, gru_export, tmp_path):
        out, entry = gru_export
        entry2 = aot.export_model(M.spec_by_name("gru_fr_en"), str(tmp_path))
        assert entry2["weights_sha256"] == entry["weights_sha256"]
        a = open(os.path.join(out, entry["encode_hlo"])).read()
        b = open(os.path.join(str(tmp_path), entry2["encode_hlo"])).read()
        assert a == b


class TestShippedArtifacts:
    """Validate artifacts/ when it exists (after `make artifacts`)."""

    def _manifest(self):
        path = os.path.join(ARTIFACTS, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        return json.load(open(path))

    def test_manifest_constants(self):
        man = self._manifest()
        assert man["n_max"] == M.N_MAX
        assert man["m_max"] == M.M_MAX
        assert man["vocab"] == M.VOCAB
        assert man["eos_id"] == M.EOS_ID
        assert len(man["models"]) == 3

    def test_all_files_exist_with_right_sizes(self):
        man = self._manifest()
        for entry in man["models"]:
            for key in ("encode_hlo", "decode_hlo", "weights_bin"):
                path = os.path.join(ARTIFACTS, entry[key])
                assert os.path.exists(path), path
            blob_size = os.path.getsize(os.path.join(ARTIFACTS, entry["weights_bin"]))
            assert blob_size == sum(p["nbytes"] for p in entry["params"])

    def test_models_in_table1_order(self):
        man = self._manifest()
        assert [m["name"] for m in man["models"]] == [
            "bilstm_de_en",
            "gru_fr_en",
            "transformer_en_zh",
        ]
