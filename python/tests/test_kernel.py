"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes/dtypes/values for every Pallas kernel and asserts
``assert_allclose`` against the pure-jnp oracle in ``compile.kernels.ref``.
These tests run at build time (``make test``); the AOT artifacts embed the
kernel lowerings, so green here means the HLO the rust runtime executes is
numerically equivalent to the reference math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention,
    attention_heads,
    gru_cell,
    lstm_cell,
    merge_heads,
    mha,
    split_heads,
)
from compile.kernels.gru_cell import gru_cell_pre
from compile.kernels.lstm_cell import lstm_cell_pre
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

# interpret-mode Pallas is slow; keep example counts tight but meaningful.
KERNEL_SETTINGS = settings(max_examples=25, deadline=None)

_dims = st.sampled_from([1, 2, 3, 4, 7, 8, 16, 32, 64, 128])
_small_dims = st.sampled_from([1, 2, 3, 4, 8, 16])
_seeds = st.integers(min_value=0, max_value=2**31 - 1)
_dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


class TestLstmCell:
    @KERNEL_SETTINGS
    @given(b=_small_dims, i=_dims, h=_dims, seed=_seeds, dtype=_dtypes)
    def test_matches_ref(self, b, i, h, seed, dtype):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = _rand(ks[0], (b, i), dtype)
        hh = _rand(ks[1], (b, h), dtype)
        cc = _rand(ks[2], (b, h), dtype)
        w_ih = _rand(ks[3], (i, 4 * h), dtype, 0.1)
        w_hh = _rand(ks[4], (h, 4 * h), dtype, 0.1)
        bias = _rand(ks[5], (4 * h,), dtype, 0.1)
        got_h, got_c = lstm_cell(x, hh, cc, w_ih, w_hh, bias)
        want_h, want_c = ref.lstm_cell_ref(
            x.astype(jnp.float32), hh.astype(jnp.float32),
            cc.astype(jnp.float32), w_ih.astype(jnp.float32),
            w_hh.astype(jnp.float32), bias.astype(jnp.float32))
        np.testing.assert_allclose(
            np.asarray(got_h, np.float32), np.asarray(want_h), **_tol(dtype))
        np.testing.assert_allclose(
            np.asarray(got_c, np.float32), np.asarray(want_c), **_tol(dtype))

    def test_gate_saturation_bounds(self):
        """|h'| = |o * tanh(c')| <= 1 elementwise, even with saturated gates."""
        k = jax.random.PRNGKey(0)
        ks = jax.random.split(k, 6)
        b, i, h = 2, 8, 16
        x = _rand(ks[0], (b, i), scale=100.0)  # saturate gates
        hh = _rand(ks[1], (b, h))
        cc = _rand(ks[2], (b, h))
        w_ih = _rand(ks[3], (i, 4 * h))
        w_hh = _rand(ks[4], (h, 4 * h))
        bias = _rand(ks[5], (4 * h,))
        got_h, _ = lstm_cell(x, hh, cc, w_ih, w_hh, bias)
        assert np.all(np.abs(np.asarray(got_h)) <= 1.0 + 1e-6)

    def test_zero_input_forget_dynamics(self):
        """With w=0, b=0: i=f=o=0.5, g=0 => c' = 0.5c, h' = 0.5*tanh(0.5c)."""
        b, i, h = 1, 4, 8
        x = jnp.zeros((b, i))
        hh = jnp.zeros((b, h))
        cc = jnp.ones((b, h))
        w_ih = jnp.zeros((i, 4 * h))
        w_hh = jnp.zeros((h, 4 * h))
        bias = jnp.zeros((4 * h,))
        got_h, got_c = lstm_cell(x, hh, cc, w_ih, w_hh, bias)
        np.testing.assert_allclose(np.asarray(got_c), 0.5, rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(got_h), 0.5 * np.tanh(0.5), rtol=1e-6)


class TestGruCell:
    @KERNEL_SETTINGS
    @given(b=_small_dims, i=_dims, h=_dims, seed=_seeds, dtype=_dtypes)
    def test_matches_ref(self, b, i, h, seed, dtype):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = _rand(ks[0], (b, i), dtype)
        hh = _rand(ks[1], (b, h), dtype)
        w_ih = _rand(ks[2], (i, 3 * h), dtype, 0.1)
        w_hh = _rand(ks[3], (h, 3 * h), dtype, 0.1)
        b_ih = _rand(ks[4], (3 * h,), dtype, 0.1)
        b_hh = _rand(ks[5], (3 * h,), dtype, 0.1)
        got = gru_cell(x, hh, w_ih, w_hh, b_ih, b_hh)
        want = ref.gru_cell_ref(
            x.astype(jnp.float32), hh.astype(jnp.float32),
            w_ih.astype(jnp.float32), w_hh.astype(jnp.float32),
            b_ih.astype(jnp.float32), b_hh.astype(jnp.float32))
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want), **_tol(dtype))

    def test_identity_when_update_gate_saturates(self):
        """Huge +bias on z => z ~ 1 => h' ~ h (GRU keeps state)."""
        b, i, h = 1, 4, 8
        x = jnp.ones((b, i))
        hh = jnp.linspace(-1, 1, h).reshape(1, h)
        w_ih = jnp.zeros((i, 3 * h))
        w_hh = jnp.zeros((h, 3 * h))
        b_ih = jnp.zeros((3 * h,)).at[h : 2 * h].set(50.0)
        b_hh = jnp.zeros((3 * h,))
        got = gru_cell(x, hh, w_ih, w_hh, b_ih, b_hh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(hh), atol=1e-5)

    def test_convex_combination_bound(self):
        """h' = (1-z)n + zh with |n|<=1 => |h'| <= max(1, |h|)."""
        ks = jax.random.split(jax.random.PRNGKey(7), 6)
        b, i, h = 3, 8, 16
        x = _rand(ks[0], (b, i))
        hh = _rand(ks[1], (b, h), scale=0.5)
        got = gru_cell(
            x, hh, _rand(ks[2], (i, 3 * h)), _rand(ks[3], (h, 3 * h)),
            _rand(ks[4], (3 * h,)), _rand(ks[5], (3 * h,)))
        bound = np.maximum(1.0, np.abs(np.asarray(hh))) + 1e-5
        assert np.all(np.abs(np.asarray(got)) <= bound)


class TestAttention:
    @KERNEL_SETTINGS
    @given(lq=_dims, lk=_dims, d=_dims, seed=_seeds, dtype=_dtypes)
    def test_matches_ref(self, lq, lk, d, seed, dtype):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = _rand(ks[0], (lq, d), dtype)
        k = _rand(ks[1], (lk, d), dtype)
        v = _rand(ks[2], (lk, d), dtype)
        # random binary mask, but never a fully-masked row
        mask_bits = jax.random.bernoulli(ks[3], 0.8, (lq, lk))
        mask_bits = mask_bits.at[:, 0].set(True)
        mask = jnp.where(mask_bits, 0.0, -1e9).astype(dtype)
        got = attention(q, k, v, mask)
        want = ref.attention_ref(
            q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), mask.astype(jnp.float32))
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want), **_tol(dtype))

    def test_fully_causal_mask_first_row_copies_v0(self):
        """Causal mask: first query attends only to k0 => out[0] == v[0]."""
        lq = lk = 8
        d = 16
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (_rand(ks[i], (lq, d)) for i in range(3))
        causal = jnp.where(
            jnp.arange(lq)[:, None] >= jnp.arange(lk)[None, :], 0.0, -1e9)
        got = attention(q, k, v, causal)
        np.testing.assert_allclose(
            np.asarray(got)[0], np.asarray(v)[0], rtol=1e-5, atol=1e-5)

    def test_uniform_scores_average_values(self):
        """q=0 => uniform softmax => output rows are mean of v."""
        lq, lk, d = 4, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        q = jnp.zeros((lq, d))
        k = _rand(ks[0], (lk, d))
        v = _rand(ks[1], (lk, d))
        got = attention(q, k, v, jnp.zeros((lq, lk)))
        want = np.tile(np.asarray(v).mean(axis=0), (lq, 1))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)

    def test_softmax_translation_invariance(self):
        """Adding a constant to the mask leaves the output unchanged."""
        lq, lk, d = 4, 8, 8
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q, k = _rand(ks[0], (lq, d)), _rand(ks[1], (lk, d))
        v = _rand(ks[2], (lk, d))
        base = attention(q, k, v, jnp.zeros((lq, lk)))
        shifted = attention(q, k, v, jnp.full((lq, lk), 3.5))
        np.testing.assert_allclose(
            np.asarray(base), np.asarray(shifted), rtol=1e-5, atol=1e-5)


class TestPreProjectedVariants:
    """The perf variants (input projection hoisted out of the recurrence)
    must be numerically identical to the fused cells."""

    @KERNEL_SETTINGS
    @given(b=_small_dims, i=_dims, h=_dims, seed=_seeds)
    def test_lstm_pre_matches_fused(self, b, i, h, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = _rand(ks[0], (b, i))
        hh = _rand(ks[1], (b, h))
        cc = _rand(ks[2], (b, h))
        w_ih = _rand(ks[3], (i, 4 * h), scale=0.1)
        w_hh = _rand(ks[4], (h, 4 * h), scale=0.1)
        bias = _rand(ks[5], (4 * h,), scale=0.1)
        fused_h, fused_c = lstm_cell(x, hh, cc, w_ih, w_hh, bias)
        pre_h, pre_c = lstm_cell_pre(x @ w_ih, hh, cc, w_hh, bias)
        np.testing.assert_allclose(
            np.asarray(fused_h), np.asarray(pre_h), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(fused_c), np.asarray(pre_c), rtol=1e-5, atol=1e-5)

    @KERNEL_SETTINGS
    @given(b=_small_dims, i=_dims, h=_dims, seed=_seeds)
    def test_gru_pre_matches_fused(self, b, i, h, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = _rand(ks[0], (b, i))
        hh = _rand(ks[1], (b, h))
        w_ih = _rand(ks[2], (i, 3 * h), scale=0.1)
        w_hh = _rand(ks[3], (h, 3 * h), scale=0.1)
        b_ih = _rand(ks[4], (3 * h,), scale=0.1)
        b_hh = _rand(ks[5], (3 * h,), scale=0.1)
        fused = gru_cell(x, hh, w_ih, w_hh, b_ih, b_hh)
        pre = gru_cell_pre(x @ w_ih + b_ih, hh, w_hh, b_hh)
        np.testing.assert_allclose(
            np.asarray(fused), np.asarray(pre), rtol=1e-5, atol=1e-5)


class TestBatchedHeads:
    """attention_heads (grid over heads) vs per-head reference."""

    @KERNEL_SETTINGS
    @given(
        lq=_small_dims, lk=_small_dims,
        n_heads=st.sampled_from([1, 2, 4]),
        dh=st.sampled_from([4, 8, 16]),
        seed=_seeds,
    )
    def test_matches_per_head(self, lq, lk, n_heads, dh, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = _rand(ks[0], (n_heads, lq, dh))
        k = _rand(ks[1], (n_heads, lk, dh))
        v = _rand(ks[2], (n_heads, lk, dh))
        mask_bits = jax.random.bernoulli(ks[3], 0.85, (lq, lk))
        mask_bits = mask_bits.at[:, 0].set(True)
        mask = jnp.where(mask_bits, 0.0, -1e9)
        got = attention_heads(q, k, v, mask)
        for hi in range(n_heads):
            want = ref.attention_ref(q[hi], k[hi], v[hi], mask)
            np.testing.assert_allclose(
                np.asarray(got[hi]), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_split_merge_roundtrip(self):
        x = jnp.arange(6 * 32, dtype=jnp.float32).reshape(6, 32)
        back = merge_heads(split_heads(x, 4))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


class TestMha:
    @KERNEL_SETTINGS
    @given(
        lq=_small_dims, lk=_small_dims,
        n_heads=st.sampled_from([1, 2, 4]),
        dh=st.sampled_from([4, 8, 16]),
        seed=_seeds,
    )
    def test_matches_ref(self, lq, lk, n_heads, dh, seed):
        d = n_heads * dh
        ks = jax.random.split(jax.random.PRNGKey(seed), 8)
        q = _rand(ks[0], (lq, d))
        k = _rand(ks[1], (lk, d))
        v = _rand(ks[2], (lk, d))
        wq, wk, wv, wo = (_rand(ks[3 + i], (d, d), scale=0.2)
                          for i in range(4))
        mask = jnp.zeros((lq, lk))
        got = mha(q, k, v, mask, wq, wk, wv, wo, n_heads)
        want = ref.mha_ref(q, k, v, mask, wq, wk, wv, wo, n_heads)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_rejects_bad_head_count(self):
        d = 12
        q = jnp.zeros((2, d))
        w = jnp.eye(d)
        with pytest.raises(AssertionError):
            mha(q, q, q, jnp.zeros((2, 2)), w, w, w, w, n_heads=5)
