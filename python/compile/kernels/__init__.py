"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from compile.kernels.attention import (
    attention,
    attention_heads,
    merge_heads,
    mha,
    split_heads,
)
from compile.kernels.gru_cell import gru_cell
from compile.kernels.lstm_cell import lstm_cell

__all__ = [
    "attention",
    "attention_heads",
    "merge_heads",
    "mha",
    "split_heads",
    "gru_cell",
    "lstm_cell",
]
