"""Fused LSTM cell as a Pallas kernel (L1).

One kernel fuses the two gate matmuls, the bias add, all four gate
non-linearities and the state update — on a real TPU this keeps the whole
cell step resident in VMEM (W_ih/W_hh for H=256 are 1 MiB each in f32,
well under the ~16 MiB VMEM budget) and feeds the MXU with a single
``[B, I+H] x [I+H, 4H]``-shaped pair of matmuls per step, instead of
bouncing the 4H-wide gate tensor through HBM between the matmul and the
element-wise tail as an unfused implementation would.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO ops. Structure (fusion,
blocking) is what we optimise; see DESIGN.md §8 for the TPU cost model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, w_ih_ref, w_hh_ref, b_ref,
                      h_out_ref, c_out_ref):
    """Pallas body: whole cell step in one VMEM-resident block."""
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    # Two MXU matmuls; accumulate in f32 regardless of input dtype.
    gates = (
        jnp.dot(x, w_ih_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, w_hh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...].astype(jnp.float32)
    )
    hsz = h.shape[-1]
    i = jax.nn.sigmoid(gates[..., 0 * hsz : 1 * hsz])
    f = jax.nn.sigmoid(gates[..., 1 * hsz : 2 * hsz])
    g = jnp.tanh(gates[..., 2 * hsz : 3 * hsz])
    o = jax.nn.sigmoid(gates[..., 3 * hsz : 4 * hsz])
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def _lstm_cell_pre_kernel(gx_ref, h_ref, c_ref, w_hh_ref, b_ref,
                          h_out_ref, c_out_ref):
    """Pallas body when the input projection ``x @ W_ih`` was hoisted out
    of the recurrence (see :func:`lstm_cell_pre`)."""
    h = h_ref[...]
    c = c_ref[...]
    gates = (
        gx_ref[...].astype(jnp.float32)
        + jnp.dot(h, w_hh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...].astype(jnp.float32)
    )
    hsz = h.shape[-1]
    i = jax.nn.sigmoid(gates[..., 0 * hsz : 1 * hsz])
    f = jax.nn.sigmoid(gates[..., 1 * hsz : 2 * hsz])
    g = jnp.tanh(gates[..., 2 * hsz : 3 * hsz])
    o = jax.nn.sigmoid(gates[..., 3 * hsz : 4 * hsz])
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def lstm_cell_pre(gx, h, c, w_hh, b):
    """LSTM cell step with a *pre-projected* input (perf variant).

    The input projection ``x @ W_ih`` is time-invariant, so an encoder
    scan can compute it for all T steps as ONE ``[T, I] x [I, 4H]`` GEMM
    before the recurrence (far better MXU/BLAS efficiency than T GEMVs)
    and feed each step its ``gx = (x @ W_ih)[t]`` row. Recorded in
    EXPERIMENTS.md §Perf.

    Args:
      gx:   ``[B, 4H]`` pre-projected input gates for this step.
      h:    ``[B, H]`` previous hidden state.
      c:    ``[B, H]`` previous cell state.
      w_hh: ``[H, 4H]`` recurrent projection.
      b:    ``[4H]`` bias.

    Returns:
      ``(h_new, c_new)``.
    """
    bsz, hsz = h.shape
    out_shape = (
        jax.ShapeDtypeStruct((bsz, hsz), h.dtype),
        jax.ShapeDtypeStruct((bsz, hsz), c.dtype),
    )
    return pl.pallas_call(
        _lstm_cell_pre_kernel,
        out_shape=out_shape,
        interpret=True,
    )(gx, h, c, w_hh, b)


@functools.partial(jax.jit, static_argnames=())
def lstm_cell(x, h, c, w_ih, w_hh, b):
    """Fused LSTM cell step (Pallas). Same contract as ``ref.lstm_cell_ref``.

    Args:
      x:    ``[B, I]`` input at this timestep.
      h:    ``[B, H]`` previous hidden state.
      c:    ``[B, H]`` previous cell state.
      w_ih: ``[I, 4H]`` input projection (gate order i,f,g,o).
      w_hh: ``[H, 4H]`` recurrent projection.
      b:    ``[4H]`` bias.

    Returns:
      ``(h_new, c_new)``, dtypes matching ``h``/``c``.
    """
    bsz, hsz = h.shape
    out_shape = (
        jax.ShapeDtypeStruct((bsz, hsz), h.dtype),
        jax.ShapeDtypeStruct((bsz, hsz), c.dtype),
    )
    return pl.pallas_call(
        _lstm_cell_kernel,
        out_shape=out_shape,
        interpret=True,
    )(x, h, c, w_ih, w_hh, b)
