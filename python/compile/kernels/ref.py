"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every kernel in this package has a reference implementation here written in
plain ``jax.numpy``. The pytest suite (``python/tests/test_kernel.py``)
asserts ``assert_allclose(kernel(...), ref(...))`` over hypothesis-generated
shape/dtype/value sweeps; the AOT path (``compile/aot.py``) lowers the
*kernel* versions so that what we test is what ships in the HLO artifacts.

Gate layouts follow the standard cuDNN/PyTorch conventions so the numbers
are directly comparable with the paper's PyTorch testbed:

* LSTM gate order: ``i, f, g, o`` (input, forget, cell, output).
* GRU gate order:  ``r, z, n``    (reset, update, new) with the
  "PyTorch-style" reset applied to the *projected* hidden state
  ``n = tanh(x W_n + r * (h U_n) + b_n)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, w_ih, w_hh, b):
    """One LSTM cell step.

    Args:
      x:    ``[B, I]`` input at this timestep.
      h:    ``[B, H]`` previous hidden state.
      c:    ``[B, H]`` previous cell state.
      w_ih: ``[I, 4H]`` input projection (gate order i,f,g,o).
      w_hh: ``[H, 4H]`` recurrent projection.
      b:    ``[4H]`` bias.

    Returns:
      ``(h_new [B,H], c_new [B,H])``.
    """
    hsz = h.shape[-1]
    gates = x @ w_ih + h @ w_hh + b
    i = jax.nn.sigmoid(gates[..., 0 * hsz : 1 * hsz])
    f = jax.nn.sigmoid(gates[..., 1 * hsz : 2 * hsz])
    g = jnp.tanh(gates[..., 2 * hsz : 3 * hsz])
    o = jax.nn.sigmoid(gates[..., 3 * hsz : 4 * hsz])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def gru_cell_ref(x, h, w_ih, w_hh, b_ih, b_hh):
    """One GRU cell step (PyTorch convention).

    Args:
      x:    ``[B, I]`` input.
      h:    ``[B, H]`` previous hidden.
      w_ih: ``[I, 3H]`` input projection (gate order r,z,n).
      w_hh: ``[H, 3H]`` recurrent projection.
      b_ih: ``[3H]`` input bias.
      b_hh: ``[3H]`` recurrent bias.

    Returns:
      ``h_new [B, H]``.
    """
    hsz = h.shape[-1]
    gi = x @ w_ih + b_ih
    gh = h @ w_hh + b_hh
    r = jax.nn.sigmoid(gi[..., 0 * hsz : 1 * hsz] + gh[..., 0 * hsz : 1 * hsz])
    z = jax.nn.sigmoid(gi[..., 1 * hsz : 2 * hsz] + gh[..., 1 * hsz : 2 * hsz])
    n = jnp.tanh(gi[..., 2 * hsz : 3 * hsz] + r * gh[..., 2 * hsz : 3 * hsz])
    return (1.0 - z) * n + z * h


def attention_ref(q, k, v, mask):
    """Masked scaled-dot-product attention, one head.

    Args:
      q:    ``[Lq, D]`` queries.
      k:    ``[Lk, D]`` keys.
      v:    ``[Lk, D]`` values.
      mask: ``[Lq, Lk]`` additive mask (0 where attend, large-negative where
            masked). ``None`` means no mask.

    Returns:
      ``[Lq, D]`` attention output.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = (q @ k.T) * scale
    if mask is not None:
        scores = scores + mask
    w = jax.nn.softmax(scores, axis=-1)
    return w @ v


def mha_ref(q, k, v, mask, wq, wk, wv, wo, n_heads):
    """Multi-head attention with learned projections (reference).

    Args:
      q, k, v: ``[Lq, D]`` / ``[Lk, D]`` / ``[Lk, D]`` token features.
      mask:    ``[Lq, Lk]`` additive mask or ``None``.
      wq/wk/wv/wo: ``[D, D]`` projections.
      n_heads: number of attention heads; ``D % n_heads == 0``.

    Returns:
      ``[Lq, D]``.
    """
    d = q.shape[-1]
    dh = d // n_heads
    qp, kp, vp = q @ wq, k @ wk, v @ wv

    def head(i):
        sl = slice(i * dh, (i + 1) * dh)
        return attention_ref(qp[:, sl], kp[:, sl], vp[:, sl], mask)

    heads = [head(i) for i in range(n_heads)]
    return jnp.concatenate(heads, axis=-1) @ wo


def layer_norm_ref(x, gamma, beta, eps=1e-5):
    """Layer norm over the last axis. ``x [..., D]``, ``gamma/beta [D]``."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def ffn_ref(x, w1, b1, w2, b2):
    """Transformer position-wise FFN: ``relu(x w1 + b1) w2 + b2``."""
    return jax.nn.relu(x @ w1 + b1) @ w2 + b2
