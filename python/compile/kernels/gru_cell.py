"""Fused GRU cell as a Pallas kernel (L1).

Single kernel for the whole GRU step (PyTorch gate convention r,z,n with
the reset gate applied to the *projected* hidden state). As with the LSTM
cell, fusing the two gate matmuls with the element-wise tail keeps the
``3H``-wide gate tensors in VMEM on TPU — the unfused version writes
``2 x [B,3H]`` intermediates to HBM per decoded token, which at M decode
steps per request is pure memory-bandwidth waste.

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_cell_kernel(x_ref, h_ref, w_ih_ref, w_hh_ref, b_ih_ref, b_hh_ref,
                     h_out_ref):
    """Pallas body: full GRU step in one VMEM-resident block."""
    x = x_ref[...]
    h = h_ref[...]
    gi = (
        jnp.dot(x, w_ih_ref[...], preferred_element_type=jnp.float32)
        + b_ih_ref[...].astype(jnp.float32)
    )
    gh = (
        jnp.dot(h, w_hh_ref[...], preferred_element_type=jnp.float32)
        + b_hh_ref[...].astype(jnp.float32)
    )
    hsz = h.shape[-1]
    r = jax.nn.sigmoid(gi[..., 0 * hsz : 1 * hsz] + gh[..., 0 * hsz : 1 * hsz])
    z = jax.nn.sigmoid(gi[..., 1 * hsz : 2 * hsz] + gh[..., 1 * hsz : 2 * hsz])
    n = jnp.tanh(gi[..., 2 * hsz : 3 * hsz] + r * gh[..., 2 * hsz : 3 * hsz])
    h_new = (1.0 - z) * n + z * h.astype(jnp.float32)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)


def _gru_cell_pre_kernel(gi_ref, h_ref, w_hh_ref, b_hh_ref, h_out_ref):
    """Pallas body when ``x @ W_ih + b_ih`` was hoisted out of the
    recurrence (see :func:`gru_cell_pre`)."""
    h = h_ref[...]
    gi = gi_ref[...].astype(jnp.float32)
    gh = (
        jnp.dot(h, w_hh_ref[...], preferred_element_type=jnp.float32)
        + b_hh_ref[...].astype(jnp.float32)
    )
    hsz = h.shape[-1]
    r = jax.nn.sigmoid(gi[..., 0 * hsz : 1 * hsz] + gh[..., 0 * hsz : 1 * hsz])
    z = jax.nn.sigmoid(gi[..., 1 * hsz : 2 * hsz] + gh[..., 1 * hsz : 2 * hsz])
    n = jnp.tanh(gi[..., 2 * hsz : 3 * hsz] + r * gh[..., 2 * hsz : 3 * hsz])
    h_new = (1.0 - z) * n + z * h.astype(jnp.float32)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)


def gru_cell_pre(gi, h, w_hh, b_hh):
    """GRU cell step with a pre-projected input (perf variant, same idea
    as ``lstm_cell_pre``: one ``[T, I] x [I, 3H]`` GEMM before the scan).

    Args:
      gi:   ``[B, 3H]`` pre-projected input gates (``x @ W_ih + b_ih``).
      h:    ``[B, H]`` previous hidden.
      w_hh: ``[H, 3H]`` recurrent projection.
      b_hh: ``[3H]`` recurrent bias.

    Returns:
      ``h_new [B, H]``.
    """
    bsz, hsz = h.shape
    return pl.pallas_call(
        _gru_cell_pre_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, hsz), h.dtype),
        interpret=True,
    )(gi, h, w_hh, b_hh)


@functools.partial(jax.jit, static_argnames=())
def gru_cell(x, h, w_ih, w_hh, b_ih, b_hh):
    """Fused GRU cell step (Pallas). Same contract as ``ref.gru_cell_ref``.

    Args:
      x:    ``[B, I]`` input.
      h:    ``[B, H]`` previous hidden.
      w_ih: ``[I, 3H]`` input projection (gate order r,z,n).
      w_hh: ``[H, 3H]`` recurrent projection.
      b_ih: ``[3H]`` input bias.
      b_hh: ``[3H]`` recurrent bias.

    Returns:
      ``h_new [B, H]`` with ``h``'s dtype.
    """
    bsz, hsz = h.shape
    return pl.pallas_call(
        _gru_cell_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, hsz), h.dtype),
        interpret=True,
    )(x, h, w_ih, w_hh, b_ih, b_hh)
