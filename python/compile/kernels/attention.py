"""Masked scaled-dot-product attention as a Pallas kernel (L1).

This is the Transformer hot spot the paper identifies (Fig. 1c): per-head
``softmax(q k^T / sqrt(d) + mask) v``. The kernel fuses score computation,
masking, a numerically-stable softmax and the value contraction so the
``[Lq, Lk]`` score matrix never leaves VMEM — at the paper's sequence
lengths (< 100 tokens) a whole head's scores are 64x64 f32 = 16 KiB, i.e.
trivially VMEM-resident; the BlockSpec grid iterates over heads, which is
exactly the HBM<->VMEM schedule a CUDA implementation would express with
one threadblock per head (DESIGN.md §Hardware-Adaptation).

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    """Pallas body for one head: fused scores+mask+softmax+values.

    Block shapes: q ``[Lq, Dh]``, k/v ``[Lk, Dh]``, mask ``[Lq, Lk]``.
    """
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + mask_ref[...].astype(jnp.float32)
    # Numerically-stable softmax, fused (scores never round-trip to HBM).
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(w, v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=())
def attention(q, k, v, mask):
    """Single-head masked attention (Pallas). Matches ``ref.attention_ref``.

    Args:
      q:    ``[Lq, D]`` queries.
      k:    ``[Lk, D]`` keys.
      v:    ``[Lk, D]`` values.
      mask: ``[Lq, Lk]`` additive mask (0 = attend, -1e9 = masked).

    Returns:
      ``[Lq, D]`` attention output, dtype of ``q``.
    """
    lq, d = q.shape
    return pl.pallas_call(
        _attention_kernel,
        out_shape=jax.ShapeDtypeStruct((lq, d), q.dtype),
        interpret=True,
    )(q, k, v, mask)


def attention_heads(q, k, v, mask):
    """All-heads masked attention in ONE Pallas call, grid over heads.

    Args:
      q:    ``[H, Lq, Dh]`` per-head queries.
      k:    ``[H, Lk, Dh]`` per-head keys.
      v:    ``[H, Lk, Dh]`` per-head values.
      mask: ``[Lq, Lk]`` additive mask, shared across heads.

    Returns:
      ``[H, Lq, Dh]``.

    The grid dimension is the head index — on TPU this is exactly the
    "one threadblock per head" schedule (DESIGN.md §Hardware-Adaptation);
    on the interpret-mode CPU path it collapses 2·layers·heads separate
    kernel invocations per decode step into one, which removed ~35% of
    the per-step dispatch overhead (EXPERIMENTS.md §Perf).
    """
    n_heads, lq, dh = q.shape
    lk = k.shape[1]
    return pl.pallas_call(
        _attention_kernel,
        grid=(n_heads,),
        in_specs=[
            pl.BlockSpec((None, lq, dh), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, lk, dh), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, lk, dh), lambda h: (h, 0, 0)),
            pl.BlockSpec((lq, lk), lambda h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, lq, dh), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_heads, lq, dh), q.dtype),
        interpret=True,
    )(q, k, v, mask)


def split_heads(x, n_heads: int):
    """``[L, D] -> [H, L, D/H]``."""
    l, d = x.shape
    return x.reshape(l, n_heads, d // n_heads).transpose(1, 0, 2)


def merge_heads(x):
    """``[H, L, Dh] -> [L, H*Dh]``."""
    h, l, dh = x.shape
    return x.transpose(1, 0, 2).reshape(l, h * dh)


def mha(q, k, v, mask, wq, wk, wv, wo, n_heads: int):
    """Multi-head attention built on the batched-head Pallas kernel.

    Projections run as plain XLA matmuls (they fuse fine on their own);
    the attention itself goes through :func:`attention_heads` — a single
    kernel call with the head index as the grid dimension.

    Args / returns: see ``ref.mha_ref``.
    """
    d = q.shape[-1]
    assert d % n_heads == 0, f"d={d} not divisible by n_heads={n_heads}"
    qp, kp, vp = q @ wq, k @ wk, v @ wv
    out = attention_heads(
        split_heads(qp, n_heads),
        split_heads(kp, n_heads),
        split_heads(vp, n_heads),
        mask,
    )
    return merge_heads(out) @ wo
