"""AOT compile path (run once by ``make artifacts``; never on request path).

For every model in ``compile.model.make_specs()`` this script:

1. initialises seeded weights and writes them as a raw little-endian
   binary blob (``artifacts/<model>.weights.bin``);
2. lowers ``encode`` and ``decode_step`` (with weights as leading HLO
   *parameters*) to **HLO text** — ``artifacts/<model>.{encode,decode}.hlo.txt``;
3. records everything the rust runtime needs to drive the greedy decode
   loop in ``artifacts/manifest.json`` (param order/shape/offset, the
   decode-input wiring of ``ModelSpec.decode_inputs``, vocab constants).

HLO **text** is the interchange format, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Weights ship as parameters rather than HLO constants: embedding tables
alone (4096 x 256 f32) would bloat the decimal-printed HLO text by ~100x
and dominate parse time at startup.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

SEED = 20220315  # fixed: artifacts are reproducible bit-for-bit


def to_hlo_text(lowered) -> str:
    """Lower a ``jax.jit(...).lower(...)`` result to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def flatten_params(params: dict):
    """Deterministic (sorted-name) flattening of a param dict."""
    names = sorted(params.keys())
    return names, [params[n] for n in names]


def np_dtype_tag(dt) -> str:
    if dt == np.float32:
        return "f32"
    if dt == np.int32:
        return "i32"
    raise ValueError(f"unsupported artifact dtype {dt}")


def export_model(spec: M.ModelSpec, out_dir: str) -> dict:
    """Export one model: weights bin + 2 HLO text files. Returns its
    manifest entry."""
    key = jax.random.PRNGKey(SEED)
    # Per-model subkey so adding a model doesn't shift existing weights.
    key = jax.random.fold_in(key, abs(hash(spec.name)) % (2**31))
    params = spec.init(key)
    names, leaves = flatten_params(params)

    # --- weights blob -----------------------------------------------------
    bin_path = os.path.join(out_dir, f"{spec.name}.weights.bin")
    offset = 0
    param_meta = []
    with open(bin_path, "wb") as f:
        for name, leaf in zip(names, leaves):
            arr = np.asarray(leaf)
            raw = arr.astype("<f4").tobytes() if arr.dtype == np.float32 \
                else arr.astype("<i4").tobytes()
            f.write(raw)
            param_meta.append({
                "name": name,
                "shape": list(arr.shape),
                "dtype": np_dtype_tag(arr.dtype),
                "offset": offset,
                "nbytes": len(raw),
            })
            offset += len(raw)

    # --- encode HLO -------------------------------------------------------
    param_sds = [jax.ShapeDtypeStruct(np.asarray(l).shape, l.dtype)
                 for l in leaves]
    n_params = len(param_sds)

    def enc_flat(*args):
        p = dict(zip(names, args[:n_params]))
        out = spec.encode(p, args[n_params], args[n_params + 1])
        return out if isinstance(out, tuple) else (out,)

    enc_lowered = jax.jit(enc_flat, keep_unused=True).lower(
        *param_sds, *M.encode_example_args())
    enc_text = to_hlo_text(enc_lowered)
    enc_path = os.path.join(out_dir, f"{spec.name}.encode.hlo.txt")
    with open(enc_path, "w") as f:
        f.write(enc_text)

    # --- decode-step HLO --------------------------------------------------
    def dec_flat(*args):
        p = dict(zip(names, args[:n_params]))
        out = spec.decode_step(p, *args[n_params:])
        return out if isinstance(out, tuple) else (out,)

    dec_args = M.decode_example_args(spec)
    dec_lowered = jax.jit(dec_flat, keep_unused=True).lower(*param_sds, *dec_args)
    dec_text = to_hlo_text(dec_lowered)
    dec_path = os.path.join(out_dir, f"{spec.name}.decode.hlo.txt")
    with open(dec_path, "w") as f:
        f.write(dec_text)

    # --- encode output metadata (shapes the rust side must allocate) ------
    enc_out_shapes = jax.eval_shape(
        enc_flat, *param_sds, *M.encode_example_args())
    enc_outputs = [{
        "shape": list(s.shape),
        "dtype": np_dtype_tag(np.dtype(s.dtype)),
    } for s in enc_out_shapes]

    print(f"  {spec.name}: {n_params} params ({offset} bytes), "
          f"encode {len(enc_text)//1024} KiB, decode {len(dec_text)//1024} KiB",
          file=sys.stderr)

    return {
        "name": spec.name,
        "lang_pair": spec.lang_pair,
        "arch": spec.arch,
        "weights_bin": os.path.basename(bin_path),
        "encode_hlo": os.path.basename(enc_path),
        "decode_hlo": os.path.basename(dec_path),
        "params": param_meta,
        "encode_outputs": enc_outputs,
        "decode_inputs": [d.to_json() for d in spec.decode_inputs],
        "n_state": spec.n_state,
        "weights_sha256": _sha256(bin_path),
    }


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for artifacts")
    ap.add_argument("--models", default="",
                    help="comma-separated subset of model names (default all)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    wanted = set(filter(None, args.models.split(",")))
    # Partial exports (--models) merge into an existing manifest so the
    # artifacts directory always describes all previously-built models.
    existing = {}
    man_path0 = os.path.join(args.out, "manifest.json")
    if wanted and os.path.exists(man_path0):
        with open(man_path0) as f:
            for entry in json.load(f).get("models", []):
                existing[entry["name"]] = entry
    entries = []
    for spec in M.make_specs():
        if wanted and spec.name not in wanted:
            if spec.name in existing:
                entries.append(existing[spec.name])
            continue
        print(f"exporting {spec.name} ...", file=sys.stderr)
        entries.append(export_model(spec, args.out))

    manifest = {
        "version": 1,
        "seed": SEED,
        "n_max": M.N_MAX,
        "m_max": M.M_MAX,
        "vocab": M.VOCAB,
        "pad_id": M.PAD_ID,
        "bos_id": M.BOS_ID,
        "eos_id": M.EOS_ID,
        "models": entries,
    }
    man_path = os.path.join(args.out, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path} ({len(entries)} models)", file=sys.stderr)


if __name__ == "__main__":
    main()
