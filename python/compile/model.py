"""L2: the three NMT architectures of the paper as JAX encode / decode-step
function pairs, built on the L1 Pallas kernels.

The paper (Sec. III) evaluates:

* a 2-layer BiLSTM encoder/decoder (OpenNMT-style, Luong attention) on
  IWSLT'14 DE-EN,
* a 1-layer GRU encoder/decoder (context-concat, no attention) on
  OPUS-100 FR-EN,
* a MarianMT-style Transformer (masked self-attn + cross-attn + FFN,
  KV-cached autoregressive decoding) on OPUS-100 EN-ZH.

Every model is exposed as two pure functions with **static shapes**
(batch 1, ``N_MAX = M_MAX = 64``, vocab 4096):

* ``encode(params, tokens i32[1,64], length i32[]) -> (ctx..., state0...)``
* ``decode_step(params, ctx..., state..., token i32[1])
     -> (next_token i32[1], state'...)``

so that ``compile/aot.py`` can lower each once to HLO text and the rust
runtime (`rust/src/runtime/seq2seq.rs`) can drive greedy autoregressive
decoding token by token — exactly the serial decode loop whose latency the
paper models as linear in M. Weights are HLO *parameters* (flattened
pytree), exported separately as binary blobs; see ``aot.py``.

Scaling note (DESIGN.md §4): hidden sizes are scaled down from the paper
(500 -> 256 for the BiLSTM; MarianMT 6L/512d -> 2L/256d) to keep the
CPU-PJRT testbed fast; the latency *structure* (encoder O(N) / O(1),
decoder O(M) serial) is preserved, and absolute scale is handled by device
calibration in the rust layer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import attention, lstm_cell, gru_cell
from compile.kernels.gru_cell import gru_cell_pre
from compile.kernels.lstm_cell import lstm_cell_pre

# ---------------------------------------------------------------------------
# Shared constants (mirrored in rust/src/runtime/vocabulary.rs)
# ---------------------------------------------------------------------------

VOCAB = 4096
N_MAX = 64  # max source length (tokens, incl. EOS)
M_MAX = 64  # max target length
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2

NEG_INF = -1e9


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    s = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype=jnp.float32) * s


def length_mask(length, size=N_MAX):
    """Additive mask ``[1, size]``: 0 for positions < length, -1e9 after."""
    pos = jnp.arange(size)
    return jnp.where(pos < length, 0.0, NEG_INF)[None, :].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BiLstmConfig:
    """2-layer BiLSTM enc / 2-layer LSTM dec with Luong dot attention."""

    vocab: int = VOCAB
    emb: int = 128
    hidden: int = 256  # per direction
    layers: int = 2


@dataclasses.dataclass(frozen=True)
class GruConfig:
    """1-layer GRU enc / dec, context concatenated to decoder input."""

    vocab: int = VOCAB
    emb: int = 128
    hidden: int = 256


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """MarianMT-style transformer (scaled down, see module docstring)."""

    vocab: int = VOCAB
    d_model: int = 256
    n_heads: int = 4
    layers: int = 2
    ffn: int = 512


# ---------------------------------------------------------------------------
# BiLSTM encoder/decoder (IWSLT'14 DE-EN analog)
# ---------------------------------------------------------------------------


def bilstm_init(key, cfg: BiLstmConfig) -> Dict[str, Any]:
    """Initialise BiLSTM params as a flat dict of named arrays."""
    ks = iter(jax.random.split(key, 64))
    p: Dict[str, Any] = {}
    p["emb_src"] = _dense_init(next(ks), (cfg.vocab, cfg.emb), 0.05)
    p["emb_tgt"] = _dense_init(next(ks), (cfg.vocab, cfg.emb), 0.05)
    h = cfg.hidden
    # Encoder: cfg.layers layers x {fwd, bwd}.
    for l in range(cfg.layers):
        isz = cfg.emb if l == 0 else 2 * h
        for d in ("fwd", "bwd"):
            p[f"enc{l}_{d}_w_ih"] = _dense_init(next(ks), (isz, 4 * h))
            p[f"enc{l}_{d}_w_hh"] = _dense_init(next(ks), (h, 4 * h))
            p[f"enc{l}_{d}_b"] = jnp.zeros((4 * h,), jnp.float32)
    # Bridge: final (fwd||bwd) states -> decoder init per layer.
    for l in range(cfg.layers):
        p[f"bridge{l}_wh"] = _dense_init(next(ks), (2 * h, h))
        p[f"bridge{l}_wc"] = _dense_init(next(ks), (2 * h, h))
    # enc_out [N, 2H] -> attention space [N, H]
    p["attn_wenc"] = _dense_init(next(ks), (2 * h, h))
    # Decoder LSTM stack.
    for l in range(cfg.layers):
        isz = cfg.emb if l == 0 else h
        p[f"dec{l}_w_ih"] = _dense_init(next(ks), (isz, 4 * h))
        p[f"dec{l}_w_hh"] = _dense_init(next(ks), (h, 4 * h))
        p[f"dec{l}_b"] = jnp.zeros((4 * h,), jnp.float32)
    # Luong output: tanh([h_top; ctx] W_out) -> logits
    p["out_w"] = _dense_init(next(ks), (2 * h, h))
    p["proj_w"] = _dense_init(next(ks), (h, cfg.vocab))
    p["proj_b"] = jnp.zeros((cfg.vocab,), jnp.float32)
    return p


# Scan unroll factor for the recurrent encoders. MEASURED (EXPERIMENTS.md
# §Perf, single-core CPU-PJRT): unroll=8 *regressed* the BiLSTM encoder
# 18.9 ms → 22.2 ms (larger loop body, worse i-cache at B=1), so the
# shipped artifacts use unroll=1. On TPU the tradeoff flips (loop dispatch
# is costlier, VMEM-resident state amortises) — re-tune when retargeting.
SCAN_UNROLL = 1


def _lstm_scan(xs, mask, h0, c0, w_ih, w_hh, b, reverse=False):
    """Masked LSTM scan over time. ``xs [T, I]``, ``mask [T]`` (1=valid).

    Padded steps do not update the state (mask gating), so the final state
    is the state at the last *valid* step regardless of padding.
    Returns ``(hs [T, H], (h_T, c_T))``.
    """

    # Perf: the input projection is time-invariant — compute it for all
    # T steps as one [T, I] x [I, 4H] GEMM instead of T GEMVs inside the
    # recurrence (EXPERIMENTS.md §Perf; same trick as cuDNN LSTM).
    gx = xs @ w_ih  # [T, 4H]

    def step(carry, inp):
        h, c = carry
        gx_t, m_t = inp
        h_new, c_new = lstm_cell_pre(gx_t[None, :], h, c, w_hh, b)
        h = jnp.where(m_t > 0, h_new, h)
        c = jnp.where(m_t > 0, c_new, c)
        return (h, c), h[0]

    (h_f, c_f), hs = jax.lax.scan(
        step, (h0, c0), (gx, mask), reverse=reverse, unroll=SCAN_UNROLL)
    return hs, (h_f, c_f)


def bilstm_encode(p, cfg: BiLstmConfig, tokens, length):
    """BiLSTM encoder.

    Args:
      p: params dict from :func:`bilstm_init`.
      tokens: ``i32[1, N_MAX]`` padded source token ids.
      length: ``i32[]`` true source length.

    Returns:
      ``(enc_attn f32[N_MAX, H], h0 f32[L,1,H], c0 f32[L,1,H])`` where
      ``enc_attn`` is the attention-space projection of the encoder output
      (used as both K and V by the decoder's Luong attention).
    """
    h = cfg.hidden
    mask = (jnp.arange(N_MAX) < length).astype(jnp.float32)
    x = p["emb_src"][tokens[0]]  # [N, E]
    finals = []
    for l in range(cfg.layers):
        zeros = jnp.zeros((1, h), jnp.float32)
        hs_f, (hf, _) = _lstm_scan(
            x, mask, zeros, zeros,
            p[f"enc{l}_fwd_w_ih"], p[f"enc{l}_fwd_w_hh"], p[f"enc{l}_fwd_b"])
        hs_b, (hb, _) = _lstm_scan(
            x, mask, zeros, zeros,
            p[f"enc{l}_bwd_w_ih"], p[f"enc{l}_bwd_w_hh"], p[f"enc{l}_bwd_b"],
            reverse=True)
        x = jnp.concatenate([hs_f, hs_b], axis=-1)  # [N, 2H]
        finals.append(jnp.concatenate([hf, hb], axis=-1))  # [1, 2H]
    enc_attn = x @ p["attn_wenc"]  # [N, H]
    h0 = jnp.stack([jnp.tanh(finals[l] @ p[f"bridge{l}_wh"])
                    for l in range(cfg.layers)])
    c0 = jnp.stack([jnp.tanh(finals[l] @ p[f"bridge{l}_wc"])
                    for l in range(cfg.layers)])
    return enc_attn, h0, c0


def bilstm_decode_step(p, cfg: BiLstmConfig, enc_attn, length, h, c, token):
    """One greedy decode step of the BiLSTM decoder.

    Args:
      enc_attn: ``f32[N_MAX, H]`` from :func:`bilstm_encode`.
      length:   ``i32[]`` source length (for the attention mask).
      h, c:     ``f32[L,1,H]`` decoder LSTM state.
      token:    ``i32[1]`` previous target token.

    Returns:
      ``(next_token i32[1], h' f32[L,1,H], c' f32[L,1,H])``.
    """
    x = p["emb_tgt"][token]  # [1, E]
    hs, cs = [], []
    for l in range(cfg.layers):
        h_l, c_l = lstm_cell(
            x, h[l], c[l], p[f"dec{l}_w_ih"], p[f"dec{l}_w_hh"], p[f"dec{l}_b"])
        hs.append(h_l)
        cs.append(c_l)
        x = h_l
    h_top = x  # [1, H]
    # Luong dot attention over encoder states (L1 Pallas attention kernel).
    ctx = attention(h_top, enc_attn, enc_attn, length_mask(length))  # [1, H]
    fused = jnp.tanh(jnp.concatenate([h_top, ctx], axis=-1) @ p["out_w"])
    logits = fused @ p["proj_w"] + p["proj_b"]  # [1, V]
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, jnp.stack(hs), jnp.stack(cs)


# ---------------------------------------------------------------------------
# GRU encoder/decoder (OPUS-100 FR-EN analog)
# ---------------------------------------------------------------------------


def gru_init(key, cfg: GruConfig) -> Dict[str, Any]:
    """Initialise GRU params as a flat dict of named arrays."""
    ks = iter(jax.random.split(key, 16))
    p: Dict[str, Any] = {}
    p["emb_src"] = _dense_init(next(ks), (cfg.vocab, cfg.emb), 0.05)
    p["emb_tgt"] = _dense_init(next(ks), (cfg.vocab, cfg.emb), 0.05)
    h = cfg.hidden
    p["enc_w_ih"] = _dense_init(next(ks), (cfg.emb, 3 * h))
    p["enc_w_hh"] = _dense_init(next(ks), (h, 3 * h))
    p["enc_b_ih"] = jnp.zeros((3 * h,), jnp.float32)
    p["enc_b_hh"] = jnp.zeros((3 * h,), jnp.float32)
    # Decoder input = [emb ; ctx]
    p["dec_w_ih"] = _dense_init(next(ks), (cfg.emb + h, 3 * h))
    p["dec_w_hh"] = _dense_init(next(ks), (h, 3 * h))
    p["dec_b_ih"] = jnp.zeros((3 * h,), jnp.float32)
    p["dec_b_hh"] = jnp.zeros((3 * h,), jnp.float32)
    p["proj_w"] = _dense_init(next(ks), (h, cfg.vocab))
    p["proj_b"] = jnp.zeros((cfg.vocab,), jnp.float32)
    return p


def gru_encode(p, cfg: GruConfig, tokens, length):
    """GRU encoder: returns the final hidden state as the context.

    Returns:
      ``ctx f32[1, H]`` — fixed-size sentence representation.
    """
    mask = (jnp.arange(N_MAX) < length).astype(jnp.float32)
    xs = p["emb_src"][tokens[0]]  # [N, E]
    h0 = jnp.zeros((1, cfg.hidden), jnp.float32)
    # Perf: hoist the input projection out of the scan (one GEMM).
    gi = xs @ p["enc_w_ih"] + p["enc_b_ih"]  # [N, 3H]

    def step(h, inp):
        gi_t, m_t = inp
        h_new = gru_cell_pre(gi_t[None, :], h, p["enc_w_hh"], p["enc_b_hh"])
        h = jnp.where(m_t > 0, h_new, h)
        return h, ()

    h_f, _ = jax.lax.scan(step, h0, (gi, mask), unroll=SCAN_UNROLL)
    return (h_f,)


def gru_decode_step(p, cfg: GruConfig, ctx, h, token):
    """One greedy decode step of the GRU decoder.

    Args:
      ctx:   ``f32[1, H]`` encoder context (constant across steps).
      h:     ``f32[1, H]`` decoder hidden state.
      token: ``i32[1]`` previous target token.

    Returns:
      ``(next_token i32[1], h' f32[1, H])``.
    """
    x = jnp.concatenate([p["emb_tgt"][token], ctx], axis=-1)
    h_new = gru_cell(x, h, p["dec_w_ih"], p["dec_w_hh"],
                     p["dec_b_ih"], p["dec_b_hh"])
    logits = h_new @ p["proj_w"] + p["proj_b"]
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, h_new


# ---------------------------------------------------------------------------
# Transformer (OPUS-100 EN-ZH / MarianMT analog)
# ---------------------------------------------------------------------------


def _sinusoidal(max_len, d):
    pos = jnp.arange(max_len)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2.0 * i / d)
    pe = jnp.zeros((max_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def transformer_init(key, cfg: TransformerConfig) -> Dict[str, Any]:
    """Initialise Transformer params as a flat dict of named arrays."""
    ks = iter(jax.random.split(key, 256))
    d, f = cfg.d_model, cfg.ffn
    p: Dict[str, Any] = {}
    p["emb"] = _dense_init(next(ks), (cfg.vocab, d), 0.05)
    for side in ("enc", "dec"):
        for l in range(cfg.layers):
            pre = f"{side}{l}"
            for w in ("wq", "wk", "wv", "wo"):
                p[f"{pre}_self_{w}"] = _dense_init(next(ks), (d, d))
            if side == "dec":
                for w in ("wq", "wk", "wv", "wo"):
                    p[f"{pre}_cross_{w}"] = _dense_init(next(ks), (d, d))
            p[f"{pre}_ffn_w1"] = _dense_init(next(ks), (d, f))
            p[f"{pre}_ffn_b1"] = jnp.zeros((f,), jnp.float32)
            p[f"{pre}_ffn_w2"] = _dense_init(next(ks), (f, d))
            p[f"{pre}_ffn_b2"] = jnp.zeros((d,), jnp.float32)
            n_ln = 3 if side == "dec" else 2
            for i in range(n_ln):
                p[f"{pre}_ln{i}_g"] = jnp.ones((d,), jnp.float32)
                p[f"{pre}_ln{i}_b"] = jnp.zeros((d,), jnp.float32)
    p["proj_w"] = _dense_init(next(ks), (d, cfg.vocab))
    p["proj_b"] = jnp.zeros((cfg.vocab,), jnp.float32)
    return p


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


# Attention-head batching strategy. MEASURED (EXPERIMENTS.md §Perf):
# the batched-head kernel (one pallas_call, grid over heads — the right
# TPU schedule, kept in kernels.attention_heads) regressed the
# interpret-mode CPU decode step 0.80 → 1.13 ms (grid slicing overhead >
# per-call dispatch at these tiny head sizes), so the CPU artifacts use
# the per-head loop. Flip for TPU targets.
BATCHED_HEADS = False


def _mha_cached(q, k_cache, v_cache, mask, wq, wo, n_heads):
    """Multi-head attention where K/V are already projected (KV cache).

    ``q [Lq, D]``, ``k_cache/v_cache [Lk, D]`` (post-projection),
    ``mask [Lq, Lk]`` additive. Heads run through the L1 Pallas kernels;
    see ``BATCHED_HEADS`` for the schedule choice.
    """
    qp = q @ wq
    if BATCHED_HEADS:
        from compile.kernels import attention_heads, merge_heads, split_heads

        out = attention_heads(
            split_heads(qp, n_heads),
            split_heads(k_cache, n_heads),
            split_heads(v_cache, n_heads),
            mask,
        )
        return merge_heads(out) @ wo
    d = q.shape[-1]
    dh = d // n_heads
    outs = []
    for i in range(n_heads):
        sl = slice(i * dh, (i + 1) * dh)
        outs.append(attention(qp[:, sl], k_cache[:, sl], v_cache[:, sl], mask))
    return jnp.concatenate(outs, axis=-1) @ wo


def transformer_encode(p, cfg: TransformerConfig, tokens, length):
    """Transformer encoder + cross-attention KV precomputation.

    Returns:
      ``(mem_k f32[L, N_MAX, D], mem_v f32[L, N_MAX, D])`` — the
      *projected* cross-attention keys/values per decoder layer. Projecting
      here (once per request) instead of in every decode step removes an
      O(M·N·D²) redundancy from the serial decode loop.
    """
    d = cfg.d_model
    x = p["emb"][tokens[0]] * jnp.sqrt(jnp.float32(d)) + _sinusoidal(N_MAX, d)
    attn_mask = jnp.broadcast_to(length_mask(length), (N_MAX, N_MAX))
    for l in range(cfg.layers):
        pre = f"enc{l}"
        sa = _mha_cached(
            x, x @ p[f"{pre}_self_wk"], x @ p[f"{pre}_self_wv"], attn_mask,
            p[f"{pre}_self_wq"], p[f"{pre}_self_wo"], cfg.n_heads)
        x = _ln(x + sa, p[f"{pre}_ln0_g"], p[f"{pre}_ln0_b"])
        ff = jax.nn.relu(x @ p[f"{pre}_ffn_w1"] + p[f"{pre}_ffn_b1"])
        ff = ff @ p[f"{pre}_ffn_w2"] + p[f"{pre}_ffn_b2"]
        x = _ln(x + ff, p[f"{pre}_ln1_g"], p[f"{pre}_ln1_b"])
    mem_k = jnp.stack([x @ p[f"dec{l}_cross_wk"] for l in range(cfg.layers)])
    mem_v = jnp.stack([x @ p[f"dec{l}_cross_wv"] for l in range(cfg.layers)])
    return mem_k, mem_v


def transformer_decode_step(p, cfg: TransformerConfig, mem_k, mem_v, length,
                            cache_k, cache_v, pos, token):
    """One KV-cached greedy decode step.

    Args:
      mem_k, mem_v: ``f32[L, N_MAX, D]`` projected cross-attn keys/values.
      length: ``i32[]`` source length (cross-attn mask).
      cache_k, cache_v: ``f32[L, M_MAX, D]`` projected self-attn KV cache.
      pos: ``i32[]`` current decode position (0-based).
      token: ``i32[1]`` previous target token (BOS at pos 0).

    Returns:
      ``(next_token i32[1], cache_k', cache_v', pos+1)`` — caches updated
      at ``pos`` and the position counter advanced, so the rust driver can
      treat the state tuple generically (``state' = outputs[1..]``).
    """
    d = cfg.d_model
    pe = _sinusoidal(M_MAX, d)
    x = p["emb"][token] * jnp.sqrt(jnp.float32(d)) + \
        jax.lax.dynamic_slice(pe, (pos, 0), (1, d))  # [1, D]
    # Self-attn mask: attend to cache positions <= pos.
    self_mask = jnp.where(jnp.arange(M_MAX) <= pos, 0.0, NEG_INF)[None, :]
    cross_mask = length_mask(length)
    for l in range(cfg.layers):
        pre = f"dec{l}"
        # Append this step's projected K/V to the layer cache at `pos`.
        k_new = x @ p[f"{pre}_self_wk"]  # [1, D]
        v_new = x @ p[f"{pre}_self_wv"]
        cache_k = jax.lax.dynamic_update_slice(cache_k, k_new[None], (l, pos, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v_new[None], (l, pos, 0))
        sa = _mha_cached(x, cache_k[l], cache_v[l], self_mask,
                         p[f"{pre}_self_wq"], p[f"{pre}_self_wo"], cfg.n_heads)
        x = _ln(x + sa, p[f"{pre}_ln0_g"], p[f"{pre}_ln0_b"])
        ca = _mha_cached(x, mem_k[l], mem_v[l], cross_mask,
                         p[f"{pre}_cross_wq"], p[f"{pre}_cross_wo"],
                         cfg.n_heads)
        x = _ln(x + ca, p[f"{pre}_ln1_g"], p[f"{pre}_ln1_b"])
        ff = jax.nn.relu(x @ p[f"{pre}_ffn_w1"] + p[f"{pre}_ffn_b1"])
        ff = ff @ p[f"{pre}_ffn_w2"] + p[f"{pre}_ffn_b2"]
        x = _ln(x + ff, p[f"{pre}_ln2_g"], p[f"{pre}_ln2_b"])
    logits = x @ p["proj_w"] + p["proj_b"]
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, cache_k, cache_v, pos + 1


# ---------------------------------------------------------------------------
# Model registry (consumed by aot.py, the pytest suite and — via the JSON
# manifest aot.py emits — the rust runtime)
# ---------------------------------------------------------------------------
#
# Runtime contract (rust/src/runtime/seq2seq.rs):
#
#   encode  inputs : (weights..., tokens i32[1,N_MAX], length i32[])
#   encode  outputs: tuple  E = (e_0, ..., e_k)
#   decode  inputs : (weights..., d_0, ..., d_m, token i32[1])
#   decode  outputs: (next_token i32[1], s_0', ..., s_j')
#
# Each decode input d_i is described by a `DecodeInput` source:
#   {"kind": "enc",    "idx": i}            — encode output i (constant per
#                                             request)
#   {"kind": "length"}                      — the source length scalar
#   {"kind": "state",  "idx": j, "init": …} — loop state: fed from decode
#                                             output j+1 on later steps;
#                                             first step from `init`, which
#                                             is either {"kind":"enc","idx":i}
#                                             or {"kind":"zeros","shape":…,
#                                             "dtype":"f32"|"i32"}
#   {"kind": "token"}                       — previous target token
# The rust driver is fully generic over this description.


@dataclasses.dataclass(frozen=True)
class DecodeInput:
    kind: str                      # "enc" | "length" | "state" | "token"
    idx: int = -1                  # enc-output or state index
    init: Any = None               # for kind == "state"

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"kind": self.kind}
        if self.kind in ("enc", "state"):
            out["idx"] = self.idx
        if self.init is not None:
            out["init"] = self.init
        return out


def _zeros_init(shape, dtype="f32"):
    return {"kind": "zeros", "shape": list(shape), "dtype": dtype}


def _enc_init(idx):
    return {"kind": "enc", "idx": idx}


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Binds a model id to its config, init/encode/decode fns and the
    decode-loop wiring used by both AOT lowering and the rust runtime."""

    name: str
    lang_pair: str            # corpus id this model is evaluated on
    arch: str                 # "bilstm" | "gru" | "transformer"
    cfg: Any
    init: Any                 # init(key) -> params dict
    encode: Any               # encode(p, tokens, length) -> tuple
    decode_step: Any          # decode_step(p, *decode_inputs_in_order)
    decode_inputs: Tuple[DecodeInput, ...]

    @property
    def n_state(self) -> int:
        return sum(1 for d in self.decode_inputs if d.kind == "state")


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_specs() -> List[ModelSpec]:
    """The three paper models, in Table-I order."""
    bi = BiLstmConfig()
    gr = GruConfig()
    tr = TransformerConfig()
    return [
        ModelSpec(
            name="bilstm_de_en",
            lang_pair="de_en",
            arch="bilstm",
            cfg=bi,
            init=lambda key: bilstm_init(key, bi),
            encode=lambda p, t, n: bilstm_encode(p, bi, t, n),
            decode_step=lambda p, enc, n, h, c, tok: bilstm_decode_step(
                p, bi, enc, n, h, c, tok),
            decode_inputs=(
                DecodeInput("enc", 0),                       # enc_attn
                DecodeInput("length"),
                DecodeInput("state", 0, _enc_init(1)),       # h <- h0
                DecodeInput("state", 1, _enc_init(2)),       # c <- c0
                DecodeInput("token"),
            ),
        ),
        ModelSpec(
            name="gru_fr_en",
            lang_pair="fr_en",
            arch="gru",
            cfg=gr,
            init=lambda key: gru_init(key, gr),
            encode=lambda p, t, n: gru_encode(p, gr, t, n),
            decode_step=lambda p, ctx, h, tok: gru_decode_step(
                p, gr, ctx, h, tok),
            decode_inputs=(
                DecodeInput("enc", 0),                       # ctx
                DecodeInput("state", 0, _zeros_init((1, gr.hidden))),
                DecodeInput("token"),
            ),
        ),
        ModelSpec(
            name="transformer_en_zh",
            lang_pair="en_zh",
            arch="transformer",
            cfg=tr,
            init=lambda key: transformer_init(key, tr),
            encode=lambda p, t, n: transformer_encode(p, tr, t, n),
            decode_step=lambda p, mk, mv, n, ck, cv, pos, tok:
                transformer_decode_step(p, tr, mk, mv, n, ck, cv, pos, tok),
            decode_inputs=(
                DecodeInput("enc", 0),                       # mem_k
                DecodeInput("enc", 1),                       # mem_v
                DecodeInput("length"),
                DecodeInput("state", 0,
                            _zeros_init((tr.layers, M_MAX, tr.d_model))),
                DecodeInput("state", 1,
                            _zeros_init((tr.layers, M_MAX, tr.d_model))),
                DecodeInput("state", 2, _zeros_init((), "i32")),  # pos
                DecodeInput("token"),
            ),
        ),
    ]


def spec_by_name(name: str) -> ModelSpec:
    for s in make_specs():
        if s.name == name:
            return s
    raise KeyError(f"unknown model spec: {name}")


def encode_example_args() -> Tuple[Any, Any]:
    """Example (tokens, length) ShapeDtypeStructs for lowering `encode`."""
    return _sds((1, N_MAX), jnp.int32), _sds((), jnp.int32)


def decode_example_args(spec: ModelSpec) -> List[Any]:
    """ShapeDtypeStructs for each decode input of `spec`, in order.

    Shapes for "enc"-sourced inputs come from `jax.eval_shape` on the
    encoder; "state" inputs from their init descriptors (zeros shape, or
    the encoder output they are seeded from).
    """
    params = jax.eval_shape(spec.init, jax.random.PRNGKey(0))
    enc_shapes = jax.eval_shape(
        spec.encode, params, *encode_example_args())
    if not isinstance(enc_shapes, (tuple, list)):
        enc_shapes = (enc_shapes,)
    args: List[Any] = []
    for d in spec.decode_inputs:
        if d.kind == "enc":
            args.append(_sds(enc_shapes[d.idx].shape, enc_shapes[d.idx].dtype))
        elif d.kind == "length":
            args.append(_sds((), jnp.int32))
        elif d.kind == "token":
            args.append(_sds((1,), jnp.int32))
        elif d.kind == "state":
            if d.init["kind"] == "enc":
                e = enc_shapes[d.init["idx"]]
                args.append(_sds(e.shape, e.dtype))
            else:
                dt = jnp.int32 if d.init["dtype"] == "i32" else jnp.float32
                args.append(_sds(tuple(d.init["shape"]), dt))
        else:
            raise ValueError(f"bad decode input kind {d.kind}")
    return args
