#!/usr/bin/env python3
"""Standalone mirror of `cnmt experiment outage` (rust/src/experiments/outage.rs).

The graceful-degradation experiment: the `hetero` fleet takes a mid-run
crash of its lead edge gateway (device 0, the fastest edge) — down for
30 s, then recovered — under two configurations sharing identical fault
physics:

  * `fleet+select`          — today's health-blind arg-min placement.
    The crash wipes the gateway's queue and in-flight batches (device
    memory is lost): those admitted requests are **stranded** forever.
    While the device is down it refuses admissions, but the blind
    selector keeps scoring it best (empty queue, fastest plane), so a
    large slice of the offered load sheds at admission for the whole
    outage window.
  * `fleet+select+failover` — the same placement with the robustness
    machinery on: the selector tracks device health (Down devices are
    excluded from the arg-min), every wiped request is re-routed
    through the selector after an exponential backoff, queue-wait
    deadline timers (k x the scored estimate) requeue stragglers, and a
    bounded retry budget sheds permanent failures. The headline: zero
    admitted requests lost, bounded p99, goodput recovering after
    re-admission.

Like the other mirrors this file re-implements the rust driver
operation for operation — keep it in lockstep with
`sim::harness::run_fleet_outage` and `experiments::outage`. The CI
`outage` matrix row diffs the two implementations at smoke and full
parameters.

Usage:
    python3 python/tools/outage_mirror.py [--out reports/outage_sweep.json]
    python3 python/tools/outage_mirror.py --requests 4000
"""

import argparse
import heapq
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from fleet_sweep_mirror import (  # noqa: E402
    CLOUD,
    EDGE,
    FLEET_HEDGE_MARGIN_S,
    FleetState,
    Telemetry,
    cell_seed,
    topo_hetero,
    topo_to_json,
)
from load_sweep_mirror import (  # noqa: E402
    BATCH_RESIDUAL,
    BUCKET_WIDTH,
    SEED,
    TTX_REFRESH_S,
    synth_workload,
    write_json,
)

# experiments::outage constants (mirror of rust/src/experiments/outage.rs).
OUTAGE_REQUESTS = 20000
OUTAGE_OFFERED_RPS = 224.0
OUTAGE_SEED_TAG = 0xFA117
OUTAGE_START_FRAC = 0.25
OUTAGE_DURATION_S = 30.0
GOODPUT_WINDOW_S = 5.0

# RetryPolicy defaults (mirror of scheduler::RetryPolicy::default).
RETRY_POLICY = {
    "timeout_mult": 4.0,
    "min_timeout_s": 0.25,
    "backoff_base_s": 0.05,
    "backoff_mult": 2.0,
    "max_retries": 4,
}

UP, DRAINING, DOWN = 0, 1, 2

# TelemetryCfg defaults (mirror of obs::TelemetryCfg::default) — the
# gauge cadence used by `--telemetry` and the detection eval.
TELEMETRY_CFG = {"interval_s": 2.0, "capacity": 64}


def neutral_fault():
    """Mirror of harness::neutral_fault: a x1.0 slow fault on lane 0
    with an infinite window — the fault-free twin's spec (exact no-op
    factors, identical control flow)."""
    return {
        "lane": 0,
        "mode": "slow",
        "factor": 1.0,
        "start_s": 0.0,
        "recover_s": float("inf"),
    }


def fault_active_at(fault, t):
    """Half-open [start_s, recover_s) window (FaultSpec::active_at)."""
    return fault["start_s"] <= t < fault["recover_s"]


def exec_factor_at(fault, lane, t):
    """FaultSpec::exec_factor_at: slow faults scale the faulted lane's
    execution inside the window; every other (mode, lane, t) is 1."""
    if fault["mode"] == "slow" and fault["lane"] == lane and fault_active_at(fault, t):
        return fault["factor"]
    return 1.0


def link_factor_at(fault, lane, t):
    """FaultSpec::link_factor_at: link faults scale the faulted cloud
    lane's transfer inside the window; everything else is 1."""
    if fault["mode"] == "link" and fault["lane"] == lane and fault_active_at(fault, t):
        return fault["factor"]
    return 1.0


def fault_to_json(fault):
    """Mirror of FaultSpec::to_json: recover_s renders null when the
    window never closes; factor only exists for slow/link modes."""
    out = {
        "lane": float(fault["lane"]),
        "mode": fault["mode"],
        "start_s": fault["start_s"],
        "recover_s": fault["recover_s"],  # inf renders as null (write_num)
    }
    if fault["mode"] in ("slow", "link"):
        out["factor"] = fault["factor"]
    return out


def outage_fault_spec(topo, requests, offered_rps):
    """Mirror of experiments::outage::outage_fault_spec: crash the lead
    edge gateway a quarter into the nominal run, recover 30 s later."""
    lane = next(i for i, d in enumerate(topo["devices"]) if d["tier"] == EDGE)
    start_s = (requests / offered_rps) * OUTAGE_START_FRAC
    return {
        "lane": lane,
        "mode": "crash",
        "start_s": start_s,
        "recover_s": start_s + OUTAGE_DURATION_S,
    }


class OutageRun:
    """One outage replay: run_fleet's open-loop arrival replay plus an
    event loop interleaving fault transitions, deadline timers and
    retry-backoff readiness — mirror of sim::harness::run_fleet_outage."""

    def __init__(
        self, pool, topo, failover, fault, retry,
        telemetry=None, detector=None, blame=None,
    ):
        self.pool = pool
        self.failover = failover
        self.fault = fault if fault is not None else neutral_fault()
        self.retry = retry
        self.st = FleetState(pool, topo, "select", FLEET_HEDGE_MARGIN_S, 0)
        if failover:
            self.st.health = [UP] * len(self.st.tiers)
            self.st.disp.armed = {}
        # Observation-only attachments (mirror of run_fleet_outage_detect):
        # gauge sampler, anomaly detector, blame ledger. All default to
        # None so the legacy replay stays operation-identical.
        self.det = detector
        self.blame = blame
        if detector is not None:
            self.st.disp.detector = detector
        self.tel = (
            Telemetry(telemetry, [d["name"] for d in topo["devices"]], False, False)
            if telemetry is not None
            else None
        )
        self.waits = [0.0] * len(self.st.tiers)
        self.retry_heap = []  # (ready_s, retry_seq, id)
        self.retry_seq = 0
        self.retries = [0] * len(pool)
        self.rejected = 0
        self.stranded = 0
        self.shed_failed = 0
        self.killed_in_flight = 0
        self.timeouts_fired = 0
        self.retry_dispatches = 0
        self.failover_reroutes = 0
        self.curve = []  # completions per GOODPUT_WINDOW_S window

    def process(self, comps):
        """Dedicated completion accounting: latency is measured from the
        request's ORIGINAL arrival (pool truth), not the copy's
        submission time — a retried request pays for its whole chain."""
        st = self.st
        fault = self.fault
        for rq, li, start_s, done_s, _bsize, _kind in comps:
            truth = self.pool[rq[1]]
            t_true = st.true_service_s(truth, li, start_s) * exec_factor_at(
                fault, li, start_s
            )
            st.useful_work_s += t_true
            tier = st.tiers[li]
            tx_s = (
                truth.t_tx * st.link_scale[li] * link_factor_at(fault, li, done_s)
                if tier == CLOUD
                else 0.0
            )
            latency = (done_s + tx_s) - truth.arrival_s
            st.hist.record(latency)
            st.stats_count += 1
            st.stats_mean += (latency - st.stats_mean) / st.stats_count
            if tier == EDGE:
                st.edge_count += 1
            else:
                st.cloud_count += 1
            st.completed += 1
            if done_s + tx_s > st.last_done_s:
                st.last_done_s = done_s + tx_s
            st.device_results[li] += 1
            wi = int((done_s + tx_s) / GOODPUT_WINDOW_S)
            while len(self.curve) <= wi:
                self.curve.append(0)
            self.curve[wi] += 1

    def exec_fn(self, li, batch, start_s):
        """Mirror of harness::OutageExecutor: the fleet's true batch
        service time with the fault's window-gated execution factor
        applied per request (x1.0 exact outside slow windows)."""
        st = self.st
        f = exec_factor_at(self.fault, li, start_s)
        mx = 0.0
        sm = 0.0
        for rq in batch:
            t = st.true_service_s(self.pool[rq[1]], li, start_s) * f
            if t > mx:
                mx = t
            sm += t
        return mx + (sm - mx) * BATCH_RESIDUAL

    def detect_taps(self, comps):
        """Mirror of harness::outage_detect_taps: transfer residuals on
        cloud completions feed the detector; the blame ledger closes
        every completed chain."""
        det, blame = self.det, self.blame
        if det is None and blame is None:
            return
        st = self.st
        fault = self.fault
        for rq, li, start_s, done_s, _bsize, _kind in comps:
            truth = self.pool[rq[1]]
            t_true = st.true_service_s(truth, li, start_s) * exec_factor_at(
                fault, li, start_s
            )
            if st.tiers[li] == CLOUD:
                tx_s = (
                    truth.t_tx * st.link_scale[li] * link_factor_at(fault, li, done_s)
                )
                if det is not None:
                    det.observe_tx(li, done_s + tx_s, tx_s, truth.n + rq[3])
            else:
                tx_s = 0.0
            if blame is not None:
                blame.complete(rq[0], start_s, done_s, t_true, tx_s)

    def sample_telemetry(self, now_s):
        """Mirror of harness::outage_sample_telemetry: claim every
        cadence point due at or before `now_s`; the same gauge reads
        feed the detector's surge charts."""
        tel = self.tel
        if tel is None:
            return
        disp = self.st.disp
        det = self.det
        while True:
            ts = tel.next_due(now_s)
            if ts is None:
                break
            for d, dev in enumerate(tel.devices):
                lane = disp.lanes[d]
                depth = float(len(lane.items) - lane.dead)
                wait = lane.expected_wait_s(ts)
                dev["queue_depth"].append(depth)
                dev["expected_wait_s"].append(wait)
                dev["in_flight"].append(
                    float(sum(1 for t in lane.free_at if t > ts))
                )
                if det is not None:
                    det.observe_gauge(d, depth, wait)
            if det is not None:
                det.commit_sample(ts)

    def submit(self, rid, now):
        """Route + submit one request copy (initial arrival or retry):
        the select path of fleet_route_and_submit, plus a queue-wait
        deadline timer when the retry policy is armed."""
        st = self.st
        truth = self.pool[rid]
        if st.ttx.is_stale(now, TTX_REFRESH_S):
            st.ttx.observe(now, truth.rtt)
        for d in range(len(st.tiers)):
            self.waits[d] = st.disp.lanes[d].expected_wait_s(now)
        trace = st.select(truth.n, self.waits)
        dev = trace["device"]
        if dev < 0:
            return False  # every device of both tiers unavailable
        bucket = int(max(trace["m_est"], 0.0) / BUCKET_WIDTH)
        rq = (rid, rid, truth.n, trace["m_est"], trace["est"], now, bucket, None)
        if st.tiers[dev] == CLOUD:
            st.ttx.observe(now, truth.rtt)
        if not st.disp.submit_lane(dev, rq):
            return False
        if self.failover:
            deadline = now + max(
                self.retry["timeout_mult"] * trace["score"],
                self.retry["min_timeout_s"],
            )
            st.disp.arm_timeout(rid, dev, deadline)
        return True

    def schedule_retry(self, rid, now):
        """Exponential backoff under a bounded retry budget; permanent
        shedding once the budget is exhausted."""
        attempt = self.retries[rid] + 1
        if attempt > self.retry["max_retries"]:
            self.shed_failed += 1
            return
        self.retries[rid] = attempt
        ready = now + self.retry["backoff_base_s"] * (
            self.retry["backoff_mult"] ** (attempt - 1)
        )
        heapq.heappush(self.retry_heap, (ready, self.retry_seq, rid))
        self.retry_seq += 1

    def run(self):
        st = self.st
        disp = st.disp
        pool = self.pool
        fault = self.fault
        inf = float("inf")
        # Crash transitions only: slow/link faults act purely through
        # their window-gated factors — no lane state to flip.
        transitions = [(fault["start_s"], 0), (fault["recover_s"], 1)]
        i = 0
        fi = 0 if fault["mode"] == "crash" else len(transitions)
        while True:
            t_arr = pool[i].arrival_s if i < len(pool) else inf
            t_tr = transitions[fi][0] if fi < len(transitions) else inf
            t_to = disp.next_timeout_s() if self.failover else None
            if t_to is None:
                t_to = inf
            t_rt = self.retry_heap[0][0] if self.retry_heap else inf
            t = min(t_tr, t_to, t_rt, t_arr)
            if t == inf:
                break
            comps = []
            disp.run_until(t, self.exec_fn, comps)
            self.process(comps)
            self.detect_taps(comps)
            self.sample_telemetry(t)
            # Fixed tie order: transition, then timeout, then retry,
            # then arrival (one action per iteration).
            if t_tr == t:
                kind = transitions[fi][1]
                fi += 1
                if kind == 0:
                    killed, n_inflight = disp.fail_lane(fault["lane"], t)
                    self.killed_in_flight += n_inflight
                    if self.failover:
                        st.health[fault["lane"]] = DOWN
                        for rq in killed:
                            self.failover_reroutes += 1
                            if self.det is not None:
                                self.det.observe_reroute(fault["lane"], t)
                            if self.blame is not None:
                                self.blame.attempt_killed(rq[0], t, False)
                            self.schedule_retry(rq[0], t)
                    else:
                        self.stranded += len(killed)
                else:
                    disp.recover_lane(fault["lane"], t)
                    if self.failover:
                        st.health[fault["lane"]] = UP
                continue
            if t_to == t:
                for rq in disp.fire_timeouts(t):
                    self.timeouts_fired += 1
                    if self.det is not None:
                        self.det.observe_timeout(t)
                    if self.blame is not None:
                        self.blame.attempt_killed(rq[0], t, True)
                    self.schedule_retry(rq[0], t)
                continue
            if t_rt == t:
                _ready, _seq, rid = heapq.heappop(self.retry_heap)
                if self.submit(rid, t):
                    self.retry_dispatches += 1
                    if self.blame is not None:
                        self.blame.attempt_start(rid, t)
                else:
                    self.schedule_retry(rid, t)
                continue
            if self.submit(i, t):
                if self.blame is not None:
                    self.blame.attempt_start(i, t)
            else:
                self.rejected += 1
            i += 1
        comps = []
        disp.run_until(inf, self.exec_fn, comps)
        self.process(comps)
        self.detect_taps(comps)
        self.sample_telemetry(st.last_done_s)
        return self.to_json()

    def to_json(self):
        st = self.st
        disp = st.disp
        offered = len(self.pool)
        admitted = offered - self.rejected
        lost = self.stranded + self.shed_failed
        assert st.completed + lost == admitted, (
            f"conservation violated: {st.completed} completed + {lost} lost "
            f"!= {admitted} admitted"
        )
        first_arrival = self.pool[0].arrival_s if self.pool else 0.0
        makespan_s = max(st.last_done_s - first_arrival, 0.0)
        max_attempts = max(self.retries) if self.retries else 0
        out = {
            "policy": "fleet+select+failover" if self.failover else "fleet+select",
            "failover": self.failover,
            "offered": float(offered),
            "admitted": float(admitted),
            "completed": float(st.completed),
            "rejected": float(self.rejected),
            "shed_rate": (self.rejected / offered) if offered else 0.0,
            "stranded": float(self.stranded),
            "shed_failed": float(self.shed_failed),
            "lost": float(lost),
            "killed_in_flight": float(self.killed_in_flight),
            "timeouts_fired": float(self.timeouts_fired),
            "retry_dispatches": float(self.retry_dispatches),
            "failover_reroutes": float(self.failover_reroutes),
            "max_attempts": float(max_attempts),
            "edge_count": float(st.edge_count),
            "cloud_count": float(st.cloud_count),
            "makespan_s": makespan_s,
            "throughput_rps": (
                st.completed / makespan_s if makespan_s > 0.0 else 0.0
            ),
            "mean_latency_s": (
                st.stats_mean if st.stats_count else float("nan")
            ),
            "p50_s": st.hist.quantile(0.50),
            "p95_s": st.hist.quantile(0.95),
            "p99_s": st.hist.quantile(0.99),
            "mean_batch": (
                disp.batch_requests / disp.batches
                if disp.batches
                else float("nan")
            ),
            "useful_work_s": st.useful_work_s,
            "device_results": [float(c) for c in st.device_results],
            "peak_depths": [float(lane.peak_depth) for lane in disp.lanes],
            "goodput_curve": [float(c) for c in self.curve],
        }
        if self.tel is not None:
            out["telemetry"] = self.tel.to_json()
        return out


def run_outage_sweep(requests, seed=SEED, telemetry=False):
    topo = topo_hetero()
    fault = outage_fault_spec(topo, requests, OUTAGE_OFFERED_RPS)
    pool = synth_workload(
        cell_seed(seed, 0) ^ OUTAGE_SEED_TAG, requests, OUTAGE_OFFERED_RPS
    )
    tel = dict(TELEMETRY_CFG) if telemetry else None
    cells = {}
    for failover in (False, True):
        r = OutageRun(
            pool, topo, failover, fault, RETRY_POLICY, telemetry=tel
        ).run()
        cells[r["policy"]] = r
    return topo, fault, cells


def outage_to_json(topo, fault, cells, requests, seed=SEED):
    base = cells["fleet+select"]
    fo = cells["fleet+select+failover"]
    return {
        "seed": float(seed),
        "requests_per_point": float(requests),
        "offered_rps": OUTAGE_OFFERED_RPS,
        "topology": topo_to_json(topo),
        "fault": {
            "lane": float(fault["lane"]),
            "mode": fault["mode"],
            "start_s": fault["start_s"],
            "recover_s": fault["recover_s"],
        },
        "retry": {
            "timeout_mult": RETRY_POLICY["timeout_mult"],
            "min_timeout_s": RETRY_POLICY["min_timeout_s"],
            "backoff_base_s": RETRY_POLICY["backoff_base_s"],
            "backoff_mult": RETRY_POLICY["backoff_mult"],
            "max_retries": float(RETRY_POLICY["max_retries"]),
        },
        "goodput_window_s": GOODPUT_WINDOW_S,
        "policies": cells,
        "headline_baseline_lost": base["lost"],
        "headline_baseline_unserved": base["offered"] - base["completed"],
        "headline_failover_lost": fo["lost"],
        "headline_failover_p99_s": fo["p99_s"],
        "headline_completed_ratio": (
            fo["completed"] / base["completed"]
            if base["completed"] > 0.0
            else float("nan")
        ),
    }


def summarize(topo, fault, cells):
    hdr = (
        f"{'policy':<22} {'offered':>8} {'admit':>7} {'done':>7} {'shed%':>6} "
        f"{'lost':>5} {'retries':>8} {'t/o':>5} {'p50ms':>8} {'p99ms':>9}"
    )
    print(hdr)
    print("-" * len(hdr))
    for label in ("fleet+select", "fleet+select+failover"):
        r = cells[label]
        print(
            f"{label:<22} {int(r['offered']):>8} {int(r['admitted']):>7} "
            f"{int(r['completed']):>7} {r['shed_rate'] * 100:>6.1f} "
            f"{int(r['lost']):>5} {int(r['retry_dispatches']):>8} "
            f"{int(r['timeouts_fired']):>5} {r['p50_s'] * 1e3:>8.1f} "
            f"{r['p99_s'] * 1e3:>9.1f}"
        )
    name = topo["devices"][fault["lane"]]["name"]
    base = cells["fleet+select"]
    fo = cells["fleet+select+failover"]
    print(
        f"\nfault: {name} (device {fault['lane']}) crashes at "
        f"t={fault['start_s']:.1f}s, recovers at t={fault['recover_s']:.1f}s "
        f"(queue + in-flight wiped)"
    )
    print(
        f"headline: failover loses {int(fo['lost'])} of "
        f"{int(fo['admitted'])} admitted requests "
        f"(p99 {fo['p99_s'] * 1e3:.0f} ms) while the blind baseline "
        f"strands {int(base['stranded'])} and sheds "
        f"{int(base['rejected'])} at admission during the outage"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--requests",
        type=int,
        default=OUTAGE_REQUESTS,
        help="requests per cell (mirrors cnmt --outage-requests)",
    )
    ap.add_argument(
        "--telemetry",
        action="store_true",
        help="sample per-device gauges at the default cadence and add a "
        "`telemetry` block per policy (mirrors cnmt experiment outage "
        "--telemetry)",
    )
    args = ap.parse_args()

    topo, fault, cells = run_outage_sweep(args.requests, telemetry=args.telemetry)
    root = outage_to_json(topo, fault, cells, args.requests)
    write_json(args.out or "reports/outage_sweep.json", root)
    summarize(topo, fault, cells)


if __name__ == "__main__":
    main()
