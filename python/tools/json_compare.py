#!/usr/bin/env python3
"""Structural JSON comparison for the CI lockstep gates.

Recursively asserts two JSON files have identical structure, identical
keys, and numerically-close leaves (rel 1e-9 / abs 1e-12 — tight enough
that only a real semantic divergence between the rust load driver and
`load_sweep_mirror.py` can trip it, loose enough to absorb libm
rounding differences between the two toolchains).

Usage:
    python3 json_compare.py A.json B.json [more_A.json more_B.json ...]
"""

import json
import math
import sys


def walk(x, y, path="$"):
    assert type(x) == type(y), f"{path}: {type(x)} vs {type(y)}"
    if isinstance(x, dict):
        assert sorted(x) == sorted(y), f"{path}: keys differ"
        for k in x:
            walk(x[k], y[k], f"{path}.{k}")
    elif isinstance(x, list):
        assert len(x) == len(y), f"{path}: length differs"
        for i, (u, v) in enumerate(zip(x, y)):
            walk(u, v, f"{path}[{i}]")
    elif isinstance(x, (int, float)) and not isinstance(x, bool):
        ok = math.isclose(float(x), float(y), rel_tol=1e-9, abs_tol=1e-12)
        assert ok, f"{path}: {x} vs {y}"
    else:
        assert x == y, f"{path}: {x} vs {y}"


def main():
    paths = sys.argv[1:]
    if len(paths) < 2 or len(paths) % 2 != 0:
        sys.exit("usage: json_compare.py A.json B.json [A2.json B2.json ...]")
    for a, b in zip(paths[0::2], paths[1::2]):
        walk(json.load(open(a)), json.load(open(b)))
        print(f"match: {a} == {b}")


if __name__ == "__main__":
    main()
