#!/usr/bin/env python3
"""Standalone mirror of `cnmt experiment load` (rust/src/experiments/load.rs).

Why this exists: the load-sweep reports checked in under `reports/` must be
regenerable in environments that have no rust toolchain (and the sweep's
dynamics need a second, independent implementation to validate against).
This script re-implements, operation for operation, exactly what the rust
driver does:

  * `util::rng::Rng`            — xoshiro256** + splitmix64 seeding, the
                                  exponential / Box-Muller draws (with the
                                  cached spare normal);
  * `experiments::load`         — the synthetic workload constants, draw
                                  order, drift scenario and closed-loop
                                  sweep;
  * `metrics::histogram`        — the geometric-bucket quantiles;
  * `scheduler::*`              — admission queue (ring buffer in
                                  rust, a plain list here), capacity
                                  tracker, length-bucketed batcher
                                  (bounded lookahead), the two-lane
                                  dispatcher's global event loop (batch
                                  starts + a pending-completion
                                  min-heap), hedged dispatch with the
                                  slab-arena race entries (each queued
                                  copy carries its race's arena index;
                                  cancellation is a state flag in the
                                  entry, not a side set of tokens);
  * `predictor::rls`            — the forgetting-factor RLS refit of the
                                  T_exe planes and of the payload-size →
                                  T_tx line;
  * `coordinator::router`       — eq. 1 with the expected-wait terms and
                                  the EWMA T_tx estimator + heartbeat
                                  (replaced by the refit T_tx line once
                                  warmed up, in adaptive runs);
  * `sim::harness`              — `run_contended` (open loop, optional
                                  drift + adaptive v2) and
                                  `run_closed_loop` (bounded-outstanding
                                  clients), and the report JSON layout
                                  (BTreeMap key order, rust f64 `Display`
                                  number formatting).

Keep this file in lockstep with the rust sources. When both toolchains are
available, `cnmt experiment load --out reports` and this script must agree
(bit-for-bit up to libm rounding).

Usage:
    python3 python/tools/load_sweep_mirror.py [--out reports/load_sweep.json]
    python3 python/tools/load_sweep_mirror.py --closed-loop \
        [--out reports/closed_loop.json]
"""

import argparse
import heapq
import math
import os

MASK = (1 << 64) - 1

# ---------------------------------------------------------------- rng (util::rng)


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded via splitmix64 (mirror of util::rng::Rng)."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s
        self.spare_normal = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def exponential(self, lam):
        while True:
            u = self.f64()
            if u > 1e-300:
                break
        return -math.log(u) / lam

    def normal(self):
        if self.spare_normal is not None:
            z, self.spare_normal = self.spare_normal, None
            return z
        while True:
            u1 = self.f64()
            if u1 > 1e-300:
                break
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        a = 2.0 * math.pi * u2
        self.spare_normal = r * math.sin(a)
        return r * math.cos(a)

    def normal_ms(self, mean, std):
        return mean + std * self.normal()


# ---------------------------------------------------------------- histogram (metrics)


def _powi(base, exp):
    """compiler-rt __powidf2: square-and-multiply, matching f64::powi."""
    recip = exp < 0
    if recip:
        exp = -exp
    r = 1.0
    a = base
    b = exp
    while True:
        if b & 1:
            r *= a
        b //= 2
        if b == 0:
            break
        a *= a
    return 1.0 / r if recip else r


class Histogram:
    """Mirror of metrics::Histogram::latency() (1e-6..1e3, 100/decade)."""

    def __init__(self, floor=1e-6, ceil=1e3, per_decade=100):
        self.floor = floor
        self.growth = math.pow(10.0, 1.0 / per_decade)
        self.ln_growth = math.log(self.growth)
        n = int(math.ceil(math.log(ceil / floor) / self.ln_growth)) + 1
        self.counts = [0] * n
        self.total = 0
        self.underflow = 0
        self.sum = 0.0

    def record(self, x):
        self.total += 1
        self.sum += x
        if x < self.floor:
            self.underflow += 1
            return
        idx = int(math.log(x / self.floor) / self.ln_growth)
        self.counts[min(idx, len(self.counts) - 1)] += 1

    def quantile(self, q):
        if self.total == 0:
            return float("nan")
        target = math.ceil(min(max(q, 0.0), 1.0) * self.total)
        seen = self.underflow
        if seen >= target and self.underflow > 0:
            return self.floor
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return self.floor * _powi(self.growth, i + 1)
        return self.floor * _powi(self.growth, len(self.counts))


# ---------------------------------------------------------------- predictor


def texe_estimate(plane, n, m):
    an, am, b = plane
    return max(an * n + am * m + b, 0.0)


def n2m_predict(gamma, delta, n):
    return max(gamma * n + delta, 1.0)


class TtxEstimator:
    """Mirror of predictor::ttx::TtxEstimator."""

    def __init__(self, alpha):
        self.alpha = alpha
        self.estimate = None
        self.last_obs_time = float("-inf")
        self.count = 0

    def observe(self, now_s, rtt_s):
        rtt_s = max(rtt_s, 0.0)
        if self.estimate is None:
            self.estimate = rtt_s
        else:
            self.estimate = self.estimate + self.alpha * (rtt_s - self.estimate)
        self.last_obs_time = now_s
        self.count += 1

    def estimate_or(self, fallback):
        return fallback if self.estimate is None else self.estimate

    def is_stale(self, now_s, max_age_s):
        return self.count == 0 or now_s - self.last_obs_time > max_age_s


class Rls2:
    """Mirror of predictor::rls::RlsLine (2x2 RLS over [x, 1] → t)."""

    def __init__(self, slope, intercept, lam, prior_var):
        self.w = [slope, intercept]
        self.p = [[prior_var, 0.0], [0.0, prior_var]]
        self.lam = lam
        self.count = 0

    def observe(self, x, t):
        if not (math.isfinite(x) and math.isfinite(t)):
            return
        p = self.p
        px0 = p[0][0] * x + p[0][1] * 1.0
        px1 = p[1][0] * x + p[1][1] * 1.0
        denom = self.lam + x * px0 + 1.0 * px1
        k0 = px0 / denom
        k1 = px1 / denom
        err = t - (x * self.w[0] + 1.0 * self.w[1])
        self.w[0] += k0 * err
        self.w[1] += k1 * err
        p[0][0] = (p[0][0] - k0 * px0) / self.lam
        p[0][1] = (p[0][1] - k0 * px1) / self.lam
        p[1][0] = (p[1][0] - k1 * px0) / self.lam
        p[1][1] = (p[1][1] - k1 * px1) / self.lam
        self.count += 1

    def estimate(self, x):
        return max(self.w[0] * x + self.w[1], 0.0)


class Rls:
    """Mirror of predictor::rls::RlsPlane (same op order — exact floats)."""

    def __init__(self, plane, lam, prior_var):
        self.w = [plane[0], plane[1], plane[2]]
        self.p = [
            [prior_var, 0.0, 0.0],
            [0.0, prior_var, 0.0],
            [0.0, 0.0, prior_var],
        ]
        self.lam = lam
        self.count = 0

    def observe(self, n, m, t):
        if not (math.isfinite(n) and math.isfinite(m) and math.isfinite(t)):
            return
        x = (n, m, 1.0)
        p = self.p
        px = [
            p[0][0] * x[0] + p[0][1] * x[1] + p[0][2] * x[2],
            p[1][0] * x[0] + p[1][1] * x[1] + p[1][2] * x[2],
            p[2][0] * x[0] + p[2][1] * x[1] + p[2][2] * x[2],
        ]
        denom = self.lam + x[0] * px[0] + x[1] * px[1] + x[2] * px[2]
        k = [px[0] / denom, px[1] / denom, px[2] / denom]
        err = t - (x[0] * self.w[0] + x[1] * self.w[1] + x[2] * self.w[2])
        for i in range(3):
            self.w[i] += k[i] * err
        for i in range(3):
            for j in range(3):
                p[i][j] = (p[i][j] - k[i] * px[j]) / self.lam
        self.count += 1


# ---------------------------------------------------------------- workload (experiments::load)

EDGE_PLANE = (1.2e-3, 3.0e-3, 6.0e-3)
CLOUD_PLANE = (0.22e-3, 0.55e-3, 26.0e-3)
N2M_GAMMA = 0.95
N2M_DELTA = 0.8
RTT_S = 0.042
MEAN_N = 17.0
M_NOISE_STD = 2.0
EXEC_NOISE_STD = 0.05
N_MAX = 62

# Drift scenario constants (experiments::load).
DRIFT_LOAD_RPS = 48.0
DRIFT_FACTOR = 2.5
DRIFT_START_FRAC = 0.25
DRIFT_RAMP_S = 10.0
DRIFT_SEED_TAG = 0xD21F7
CLOSED_SEED_TAG = 0xC105ED

# AdaptiveOpts::default() (sim::harness).
ADAPTIVE_DEFAULTS = {
    "hedge_margin_s": 0.010,
    "rls_lambda": 0.998,
    "rls_prior_var": 1.0,
    "refit_min_obs": 64,
    "refit_ttx": True,
    "waste_budget": 0.10,
}

# scheduler::hedge constants (the waste-budget margin controller).
HEDGE_GAIN = 0.05
HEDGE_WINDOW_DECAY = 0.998
HEDGE_MIN_MARGIN_S = 1e-4
HEDGE_MAX_MARGIN_S = 0.050


class HedgeBudget:
    """Mirror of scheduler::hedge::HedgeBudget (same op order — exact
    floats): adapts the hedge margin online to cap the wasted-work
    fraction at the configured budget."""

    def __init__(self, budget_frac, init_margin_s):
        self.budget = budget_frac
        self.margin_s = min(max(init_margin_s, HEDGE_MIN_MARGIN_S), HEDGE_MAX_MARGIN_S)
        self.useful_s = 0.0
        self.wasted_s = 0.0

    def observe(self, t_s, wasted):
        if not (math.isfinite(t_s) and t_s >= 0.0):
            return
        self.useful_s *= HEDGE_WINDOW_DECAY
        self.wasted_s *= HEDGE_WINDOW_DECAY
        if wasted:
            self.wasted_s += t_s
        else:
            self.useful_s += t_s
        total = self.useful_s + self.wasted_s
        if total > 0.0:
            frac = self.wasted_s / total
            err = (self.budget - frac) / self.budget
            m = self.margin_s * (1.0 + HEDGE_GAIN * err)
            self.margin_s = min(max(m, HEDGE_MIN_MARGIN_S), HEDGE_MAX_MARGIN_S)


def _round_half_away(x):
    """f64::round (half away from zero); python round() is banker's."""
    return math.floor(x + 0.5) if x >= 0.0 else math.ceil(x - 0.5)


class RequestTruth:
    __slots__ = ("n", "m_real", "arrival_s", "t_edge", "t_cloud", "t_tx", "rtt")

    def __init__(self, n, m_real, arrival_s, t_edge, t_cloud, t_tx, rtt):
        self.n = n
        self.m_real = m_real
        self.arrival_s = arrival_s
        self.t_edge = t_edge
        self.t_cloud = t_cloud
        self.t_tx = t_tx
        self.rtt = rtt


def synth_workload(seed, count, offered_rps):
    rng = Rng(seed)
    requests = []
    t = 0.0
    sum_m = 0.0
    for _ in range(count):
        t += rng.exponential(offered_rps)
        n = 1 + min(int(rng.exponential(1.0 / MEAN_N)), N_MAX - 1)
        m_mean = N2M_GAMMA * n + N2M_DELTA
        m = _round_half_away(m_mean + rng.normal_ms(0.0, M_NOISE_STD))
        m = int(min(max(m, 1.0), float(N_MAX)))
        noise_e = max(1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD), 0.2)
        noise_c = max(1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD), 0.2)
        requests.append(
            RequestTruth(
                n,
                m,
                t,
                texe_estimate(EDGE_PLANE, n, m) * noise_e,
                texe_estimate(CLOUD_PLANE, n, m) * noise_c,
                RTT_S,
                RTT_S,
            )
        )
        sum_m += m
    return requests


# ---------------------------------------------------------------- scheduler (v2)

EDGE, CLOUD = 0, 1
BUCKET_WIDTH = 8.0
MAX_BATCH = 8
LOOKAHEAD = 32
MAX_QUEUE_DEPTH = 512
EDGE_WORKERS = 1
CLOUD_WORKERS = 4
BATCH_RESIDUAL = 0.15
TTX_REFRESH_S = 60.0
TTX_ALPHA = 0.3
TTX_PRIOR = 0.05

# QueuedRequest tuple indices: (id, payload, n, m_est, est_service_s,
# arrival_s, bucket, hedge) — `hedge` mirrors the rust slab key: the
# index of the in-flight race entry in the dispatcher's arena, or None
# for solo submissions.
SOLO, WIN, LOSS = 0, 1, 2
QUEUED, RUNNING, DONE, CANCELLED = 0, 1, 2, 3


class Lane:
    """AdmissionQueue (ring buffer) + CapacityTracker for one device."""

    def __init__(self, workers):
        # A python list mirrors the rust ring buffer's access profile
        # (O(1) indexing for the batcher's lookahead; head pops are a
        # C-level memmove).
        self.items = []
        self.free_at = [0.0] * workers
        self.backlog_est_s = 0.0
        # Cancelled-but-unpurged entries: hold no admission slot.
        self.dead = 0
        self.offered = 0
        self.admitted = 0
        self.rejected = 0
        self.peak_depth = 0

    def has_room(self):
        return len(self.items) - self.dead < MAX_QUEUE_DEPTH

    def offer(self, rq):
        self.offered += 1
        if not self.has_room():
            self.rejected += 1
            return False
        self.items.append(rq)
        self.admitted += 1
        self.peak_depth = max(self.peak_depth, len(self.items) - self.dead)
        self.backlog_est_s += max(rq[4], 0.0)
        return True

    def earliest_free(self):
        best_i, best_t = 0, self.free_at[0]
        for i in range(1, len(self.free_at)):
            if self.free_at[i] < best_t:
                best_i, best_t = i, self.free_at[i]
        return best_i, best_t

    def expected_wait_s(self, now_s):
        inflight = 0.0
        for t in self.free_at:
            if t > now_s:
                inflight += t - now_s
        return (inflight + self.backlog_est_s) / len(self.free_at)

    def on_cancel(self, est):
        self.backlog_est_s = max(self.backlog_est_s - max(est, 0.0), 0.0)


class Dispatcher:
    """Mirror of scheduler::Dispatcher (global event loop + hedging on
    the slab-arena race entries — no id-keyed maps, no cancel-token
    set)."""

    def __init__(self):
        self.lanes = [Lane(EDGE_WORKERS), Lane(CLOUD_WORKERS)]
        self.batches = 0
        self.batch_requests = 0
        # Pending completion min-heap: (done_s, seq, start_s, batch_size,
        # device, rq). seq is unique, so comparisons never reach rq.
        self.pending = []
        self.seq = 0
        # Hedge arena (mirror of util::slab): entry =
        # [est_edge, est_cloud, state_edge, state_cloud, winner];
        # freed slots are recycled through the free list. Python needs
        # no generation counter — entries are only dereferenced through
        # live queue records — but the recycling discipline is the same.
        self.arena = []
        self.arena_free = []
        self.hs_hedged = 0
        self.hs_wins = [0, 0]
        self.hs_cancelled = 0
        self.hs_losers = 0

    def arena_alloc(self, entry):
        if self.arena_free:
            idx = self.arena_free.pop()
            self.arena[idx] = entry
            return idx
        self.arena.append(entry)
        return len(self.arena) - 1

    def arena_release(self, idx):
        self.arena[idx] = None
        self.arena_free.append(idx)

    def submit(self, device, rq):
        return self.lanes[device].offer(rq)

    def submit_hedged(self, rq, est_edge, est_cloud):
        # Room is checked up front so the race entry is allocated only
        # when both copies are guaranteed admission (same predicate
        # offer() applies).
        if self.lanes[EDGE].has_room() and self.lanes[CLOUD].has_room():
            idx = self.arena_alloc([est_edge, est_cloud, QUEUED, QUEUED, None])
            edge_rq = rq[:4] + (est_edge,) + rq[5:7] + (idx,)
            cloud_rq = rq[:4] + (est_cloud,) + rq[5:7] + (idx,)
            self.lanes[EDGE].offer(edge_rq)
            self.lanes[CLOUD].offer(cloud_rq)
            self.hs_hedged += 1
            return "hedged"
        edge_rq = rq[:4] + (est_edge,) + rq[5:]
        cloud_rq = rq[:4] + (est_cloud,) + rq[5:]
        edge_ok = self.lanes[EDGE].offer(edge_rq)
        cloud_ok = self.lanes[CLOUD].offer(cloud_rq)
        if edge_ok:
            return "single_edge"
        if cloud_ok:
            return "single_cloud"
        return "rejected"

    def lane_next_start(self, device):
        # is_ghost() is inlined in this and the batcher loop: they are
        # the mirror's hottest paths and python call overhead dominates.
        lane = self.lanes[device]
        arena = self.arena
        while True:
            if not lane.items:
                return None
            head = lane.items[0]
            hid = head[7]
            if hid is not None and arena[hid][2 + device] == CANCELLED:
                lane.items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
                continue
            _w, free_s = lane.earliest_free()
            return max(free_s, head[5])

    def next_batch_start(self):
        e = self.lane_next_start(EDGE)
        c = self.lane_next_start(CLOUD)
        if e is None and c is None:
            return None
        if c is None or (e is not None and e <= c):
            return (EDGE, e)
        return (CLOUD, c)

    def next_event_s(self):
        ns = self.next_batch_start()
        nd = self.pending[0][0] if self.pending else None
        if ns is None and nd is None:
            return None
        if ns is None:
            return nd
        if nd is None:
            return ns[1]
        return min(ns[1], nd)

    def form_batch(self, lane, device, start_s):
        items = lane.items
        arena = self.arena
        while True:
            if not items:
                return []
            head = items[0]
            hid = head[7]
            if hid is not None and arena[hid][2 + device] == CANCELLED:
                items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
            else:
                break
        head = items.pop(0)
        bucket = head[6]
        batch = [head]
        i = 0
        scanned = 0
        while len(batch) < MAX_BATCH and scanned < LOOKAHEAD:
            if i >= len(items):
                break
            rq = items[i]
            hid = rq[7]
            if hid is not None and arena[hid][2 + device] == CANCELLED:
                del items[i]
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
                continue
            if rq[6] == bucket and rq[5] <= start_s:
                batch.append(rq)
                del items[i]
            else:
                i += 1
            scanned += 1
        return batch

    def dispatch_at(self, device, start_s, exec_fn):
        lane = self.lanes[device]
        batch = self.form_batch(lane, device, start_s)
        if not batch:
            return
        for rq in batch:
            if rq[7] is not None:
                self.arena[rq[7]][2 + device] = RUNNING
        est_sum = 0.0
        for rq in batch:
            est_sum += rq[4]
        service_s = max(exec_fn(device, batch, start_s), 0.0)
        done_s = start_s + service_s
        worker, _free = lane.earliest_free()
        lane.backlog_est_s = max(lane.backlog_est_s - est_sum, 0.0)
        lane.free_at[worker] = done_s
        self.batches += 1
        self.batch_requests += len(batch)
        bsize = len(batch)
        for rq in batch:
            heapq.heappush(
                self.pending, (done_s, self.seq, start_s, bsize, device, rq)
            )
            self.seq += 1

    def resolve_completion(self, device, hedge_idx):
        if hedge_idx is None:
            return SOLO
        h = self.arena[hedge_idx]
        h[2 + device] = DONE
        if h[4] is not None:
            self.arena_release(hedge_idx)
            self.hs_losers += 1
            return LOSS
        h[4] = device
        self.hs_wins[device] += 1
        twin = 1 - device
        if h[2 + twin] == QUEUED:
            # Mark the twin cancelled in the race entry itself; the
            # ghost is purged lazily, which also frees the entry.
            h[2 + twin] = CANCELLED
            self.hs_cancelled += 1
            self.lanes[twin].on_cancel(h[twin])
            self.lanes[twin].dead += 1
        return WIN

    def flush_one(self, out):
        done_s, _seq, start_s, bsize, device, rq = heapq.heappop(self.pending)
        kind = self.resolve_completion(device, rq[7])
        out.append((rq, device, start_s, done_s, bsize, kind))

    def step(self, horizon_s, exec_fn, out):
        ns = self.next_batch_start()
        nd = self.pending[0][0] if self.pending else None
        if ns is None and nd is None:
            return False
        completion_first = ns is None or (nd is not None and nd <= ns[1])
        if completion_first:
            if nd > horizon_s:
                return False
            self.flush_one(out)
        else:
            device, start_s = ns
            if start_s > horizon_s:
                return False
            self.dispatch_at(device, start_s, exec_fn)
        return True

    def run_until(self, horizon_s, exec_fn, out):
        while self.step(horizon_s, exec_fn, out):
            pass


# ---------------------------------------------------------------- harness

EDGE_ONLY, CLOUD_ONLY, CNMT = "edge_only", "cloud_only", "cnmt"


def drift_factor_at(drift, t_s):
    _device, start_s, ramp_s, factor = drift
    if t_s <= start_s:
        return 1.0
    if ramp_s <= 0.0:
        return factor
    frac = min((t_s - start_s) / ramp_s, 1.0)
    return 1.0 + (factor - 1.0) * frac


def true_service_s(truth, device, start_s, drift):
    base = truth.t_edge if device == EDGE else truth.t_cloud
    if drift is not None and drift[0] == device:
        return base * drift_factor_at(drift, start_s)
    return base


class Acct:
    """Mirror of sim::harness::Acct (Welford mean, as metrics::stats)."""

    def __init__(self):
        self.hist = Histogram()
        self.stats_count = 0
        self.stats_mean = 0.0
        self.edge_count = 0
        self.cloud_count = 0
        self.completed = 0
        self.last_done_s = 0.0
        self.useful_work_s = 0.0
        self.wasted_work_s = 0.0

    def on_completion(self, comp, t_true_s, tx_s, ctl):
        rq, device, _start_s, done_s, _bsize, kind = comp
        if kind == LOSS:
            self.wasted_work_s += t_true_s
            if ctl is not None:
                ctl.observe(t_true_s, True)
            return False
        self.useful_work_s += t_true_s
        if ctl is not None:
            ctl.observe(t_true_s, False)
        latency = (done_s - rq[5]) + tx_s
        self.hist.record(latency)
        self.stats_count += 1
        self.stats_mean += (latency - self.stats_mean) / self.stats_count
        if device == EDGE:
            self.edge_count += 1
        else:
            self.cloud_count += 1
        self.completed += 1
        if done_s + tx_s > self.last_done_s:
            self.last_done_s = done_s + tx_s
        return True

    def process(self, comps, pool, drift, st, on_result):
        for comp in comps:
            rq, device, start_s, _done_s, _bsize, _kind = comp
            truth = pool[rq[1]]
            t_true = true_service_s(truth, device, start_s, drift)
            tx_s = truth.t_tx if device == CLOUD else 0.0
            is_result = self.on_completion(comp, t_true, tx_s, st.ctl)
            if st.rls is not None:
                st.rls[device].observe(float(truth.n), float(truth.m_real), t_true)
                if device == CLOUD and st.adaptive["refit_ttx"]:
                    # A cloud completion is a timestamped transfer:
                    # n tokens went out, m came back.
                    st.rls_ttx.observe(float(truth.n + truth.m_real), truth.t_tx)
            if is_result and on_result is not None:
                on_result(comp)


class RunState:
    """Everything one contended run carries (router + planes + acct)."""

    def __init__(self, pool, policy, queue_aware, adaptive, drift):
        self.pool = pool
        self.policy = policy
        self.queue_aware = queue_aware
        self.adaptive = adaptive
        self.drift = drift
        self.ttx = TtxEstimator(TTX_ALPHA)
        self.disp = Dispatcher()
        self.acct = Acct()
        self.texe_e = EDGE_PLANE
        self.texe_c = CLOUD_PLANE
        if adaptive is not None:
            self.rls = [
                Rls(EDGE_PLANE, adaptive["rls_lambda"], adaptive["rls_prior_var"]),
                Rls(CLOUD_PLANE, adaptive["rls_lambda"], adaptive["rls_prior_var"]),
            ]
            # Payload-size → T_tx refit line (mirror of harness Refit.ttx:
            # diffuse start at zero, installed once refit_min_obs
            # transfers are seen).
            self.rls_ttx = Rls2(
                0.0, 0.0, adaptive["rls_lambda"], adaptive["rls_prior_var"]
            )
            # Waste-budget margin controller (AdaptiveOpts::budget_ctl):
            # active when hedging is enabled and a budget is configured.
            if adaptive["hedge_margin_s"] > 0.0 and adaptive.get("waste_budget", 0.0) > 0.0:
                self.ctl = HedgeBudget(adaptive["waste_budget"], adaptive["hedge_margin_s"])
            else:
                self.ctl = None
        else:
            self.rls = None
            self.rls_ttx = None
            self.ctl = None

    def exec_fn(self, device, batch, start_s):
        mx = 0.0
        sm = 0.0
        for rq in batch:
            truth = self.pool[rq[1]]
            t = true_service_s(truth, device, start_s, self.drift)
            if t > mx:
                mx = t
            sm += t
        return mx + (sm - mx) * BATCH_RESIDUAL


def apply_refit(st):
    if st.adaptive is None:
        return
    rls_e, rls_c = st.rls
    if rls_e.count >= st.adaptive["refit_min_obs"]:
        st.texe_e = (rls_e.w[0], rls_e.w[1], rls_e.w[2])
    if rls_c.count >= st.adaptive["refit_min_obs"]:
        st.texe_c = (rls_c.w[0], rls_c.w[1], rls_c.w[2])


def route_and_submit(st, rq_id, truth, now):
    """Mirror of sim::harness::route_and_submit. Returns admitted."""
    if st.ttx.is_stale(now, TTX_REFRESH_S):
        st.ttx.observe(now, truth.rtt)
    if st.queue_aware:
        edge_wait = st.disp.lanes[EDGE].expected_wait_s(now)
        cloud_wait = st.disp.lanes[CLOUD].expected_wait_s(now)
    else:
        edge_wait = cloud_wait = 0.0
    ttx_est = st.ttx.estimate_or(TTX_PRIOR)
    m_est = n2m_predict(N2M_GAMMA, N2M_DELTA, truth.n)
    if st.policy == EDGE_ONLY:
        device = EDGE
        t_e = t_c = float("nan")
    elif st.policy == CLOUD_ONLY:
        device = CLOUD
        t_e = t_c = float("nan")
    else:
        # Refit T_tx law (Router::set_ttx_line): once warmed up it
        # replaces the EWMA with a·(N + M̂) + b, clamped at 0.
        if (
            st.rls_ttx is not None
            and st.adaptive["refit_ttx"]
            and st.rls_ttx.count >= st.adaptive["refit_min_obs"]
        ):
            ttx_est = st.rls_ttx.estimate(truth.n + m_est)
        t_e = texe_estimate(st.texe_e, truth.n, m_est)
        t_c = texe_estimate(st.texe_c, truth.n, m_est)
        device = EDGE if t_e + edge_wait <= ttx_est + t_c + cloud_wait else CLOUD
    hedge = False
    if st.adaptive is not None:
        bar = st.ctl.margin_s if st.ctl is not None else st.adaptive["hedge_margin_s"]
        margin = (t_e + edge_wait) - (ttx_est + t_c + cloud_wait)
        hedge = bar > 0.0 and math.isfinite(margin) and abs(margin) <= bar
    bucket = int(max(m_est, 0.0) / BUCKET_WIDTH)
    if hedge:
        # The trace already evaluated both planes at (n, M̂): the rust
        # harness reuses those evaluations (same floats as re-evaluating).
        rq = (rq_id, rq_id, truth.n, m_est, 0.0, now, bucket, None)
        outcome = st.disp.submit_hedged(rq, t_e, t_c)
        # Only a cloud copy actually in flight refreshes T_tx.
        if outcome in ("hedged", "single_cloud"):
            st.ttx.observe(now, truth.rtt)
        return outcome != "rejected"
    if device == CLOUD:
        st.ttx.observe(now, truth.rtt)
    if st.policy == EDGE_ONLY or st.policy == CLOUD_ONLY:
        est = texe_estimate(
            st.texe_e if device == EDGE else st.texe_c, truth.n, m_est
        )
    else:
        est = t_e if device == EDGE else t_c
    rq = (rq_id, rq_id, truth.n, m_est, est, now, bucket, None)
    return st.disp.submit(device, rq)


def policy_label(policy, queue_aware, adaptive):
    if adaptive is not None:
        return policy + ("+adaptive" if queue_aware else "+adaptive-blind")
    if queue_aware:
        return policy + "+queue"
    return policy


def finish_contended(st, offered, rejected, makespan_s):
    disp = st.disp
    acct = st.acct
    hedged = disp.hs_hedged
    useful = acct.useful_work_s
    wasted = acct.wasted_work_s
    total_work = useful + wasted
    mean_batch = (
        disp.batch_requests / disp.batches if disp.batches else float("nan")
    )
    out = {
        "policy": policy_label(st.policy, st.queue_aware, st.adaptive),
        "queue_aware": st.queue_aware,
        "adaptive": st.adaptive is not None,
        "offered": float(offered),
        "completed": float(acct.completed),
        "rejected": float(rejected),
        "shed_rate": (rejected / offered) if offered else 0.0,
        "edge_count": float(acct.edge_count),
        "cloud_count": float(acct.cloud_count),
        "makespan_s": makespan_s,
        "throughput_rps": acct.completed / makespan_s if makespan_s > 0.0 else 0.0,
        "mean_latency_s": acct.stats_mean if acct.stats_count else float("nan"),
        "p50_s": acct.hist.quantile(0.50),
        "p95_s": acct.hist.quantile(0.95),
        "p99_s": acct.hist.quantile(0.99),
        "mean_batch": mean_batch,
        "edge_peak_depth": float(disp.lanes[EDGE].peak_depth),
        "cloud_peak_depth": float(disp.lanes[CLOUD].peak_depth),
        "hedged": float(hedged),
        "hedge_rate": (hedged / offered) if offered else 0.0,
        "hedge_wins_edge": float(disp.hs_wins[EDGE]),
        "hedge_wins_cloud": float(disp.hs_wins[CLOUD]),
        "hedge_cancelled": float(disp.hs_cancelled),
        "hedge_wasted": float(disp.hs_losers),
        "useful_work_s": useful,
        "wasted_work_s": wasted,
        "wasted_frac": wasted / total_work if total_work > 0.0 else 0.0,
    }
    # Only budget-controlled runs carry the key (legacy rows keep their
    # schema byte-for-byte) — mirror of ContendedResult::to_json.
    if st.ctl is not None:
        out["hedge_final_margin_s"] = st.ctl.margin_s
    return out


def run_contended(pool, policy, queue_aware, adaptive=None, drift=None):
    st = RunState(pool, policy, queue_aware, adaptive, drift)
    rejected = 0
    for i, truth in enumerate(pool):
        now = truth.arrival_s
        comps = []
        st.disp.run_until(now, st.exec_fn, comps)
        st.acct.process(comps, pool, drift, st, None)
        if adaptive is not None:
            apply_refit(st)
        if not route_and_submit(st, i, truth, now):
            rejected += 1
    comps = []
    st.disp.run_until(float("inf"), st.exec_fn, comps)
    st.acct.process(comps, pool, drift, st, None)
    first_arrival = pool[0].arrival_s if pool else 0.0
    makespan_s = max(st.acct.last_done_s - first_arrival, 0.0)
    return finish_contended(st, len(pool), rejected, makespan_s)


def run_closed_loop(pool, policy, queue_aware, adaptive, clients, think_s, drift=None):
    total = len(pool)
    st = RunState(pool, policy, queue_aware, adaptive, drift)
    ready_s = [0.0] * clients
    waiting = [False] * clients
    client_of = [0] * total
    next_body = 0
    rejected = 0
    resolved = [0]

    while resolved[0] < total:
        t_submit = float("inf")
        client = -1
        if next_body < total:
            for k in range(clients):
                if not waiting[k] and ready_s[k] < t_submit:
                    t_submit = ready_s[k]
                    client = k
        next_event = st.disp.next_event_s()
        submit_first = client != -1 and (next_event is None or t_submit <= next_event)
        if submit_first:
            body = next_body
            next_body += 1
            client_of[body] = client
            if route_and_submit(st, body, pool[body], t_submit):
                waiting[client] = True
            else:
                rejected += 1
                resolved[0] += 1
        else:
            if next_event is None:
                break
            comps = []
            st.disp.step(next_event, st.exec_fn, comps)

            def on_result(comp):
                k = client_of[comp[0][1]]
                # The client only sees the result after the network
                # transit — the same t_tx the latency metric charges.
                tx_s = pool[comp[0][1]].t_tx if comp[1] == CLOUD else 0.0
                waiting[k] = False
                ready_s[k] = comp[3] + tx_s + think_s
                resolved[0] += 1

            st.acct.process(comps, pool, drift, st, on_result)
            if adaptive is not None:
                apply_refit(st)
    comps = []
    st.disp.run_until(float("inf"), st.exec_fn, comps)
    st.acct.process(comps, pool, drift, st, None)
    makespan_s = max(st.acct.last_done_s, 0.0)
    return finish_contended(st, total, rejected, makespan_s)


# ---------------------------------------------------------------- sweeps + json

SEED = 20220315
REQUESTS_PER_POINT = 20000
LOADS_RPS = [4.0, 8.0, 16.0, 32.0, 64.0, 96.0, 128.0]
CONFIGURATIONS = [
    (EDGE_ONLY, False, False),
    (CLOUD_ONLY, False, False),
    (CNMT, False, False),
    (CNMT, True, False),
    (CNMT, True, True),
]
CLOSED_CONFIGURATIONS = [
    (CLOUD_ONLY, False, False),
    (CNMT, True, False),
    (CNMT, True, True),
]
DEFAULT_CLIENTS = [1, 2, 4, 8, 16, 32, 64]


def run_drift(seed, requests_per_point):
    pool = synth_workload(seed ^ DRIFT_SEED_TAG, requests_per_point, DRIFT_LOAD_RPS)
    start_s = (requests_per_point / DRIFT_LOAD_RPS) * DRIFT_START_FRAC
    spec = (EDGE, start_s, DRIFT_RAMP_S, DRIFT_FACTOR)
    policies = {}
    for policy, aware, adaptive in [
        (CNMT, False, False),
        (CNMT, True, False),
        (CNMT, True, True),
    ]:
        r = run_contended(
            pool,
            policy,
            aware,
            ADAPTIVE_DEFAULTS if adaptive else None,
            spec,
        )
        policies[r["policy"]] = r
    return {
        "spec": {
            "device": "edge",
            "start_s": start_s,
            "ramp_s": DRIFT_RAMP_S,
            "factor": DRIFT_FACTOR,
        },
        "offered_rps": DRIFT_LOAD_RPS,
        "policies": policies,
        "headline_p99_ratio": policies["cnmt+queue"]["p99_s"]
        / policies["cnmt+adaptive"]["p99_s"],
    }


def run_sweep(loads_rps=None, requests_per_point=None):
    loads_rps = LOADS_RPS if loads_rps is None else loads_rps
    requests_per_point = (
        REQUESTS_PER_POINT if requests_per_point is None else requests_per_point
    )
    points = []
    for i, load in enumerate(loads_rps):
        seed = SEED ^ (((i + 1) * 0x9E3779B97F4A7C15) & MASK)
        pool = synth_workload(seed, requests_per_point, load)
        policies = {}
        for policy, aware, adaptive in CONFIGURATIONS:
            r = run_contended(
                pool, policy, aware, ADAPTIVE_DEFAULTS if adaptive else None
            )
            policies[r["policy"]] = r
        points.append({"offered_rps": load, "policies": policies})
    return points


def run_closed_sweep(clients_list=None, requests_per_point=None, think_s=0.0):
    clients_list = DEFAULT_CLIENTS if clients_list is None else clients_list
    requests_per_point = (
        REQUESTS_PER_POINT if requests_per_point is None else requests_per_point
    )
    pool = synth_workload(SEED ^ CLOSED_SEED_TAG, requests_per_point, 1.0)
    points = []
    for clients in clients_list:
        policies = {}
        for policy, aware, adaptive in CLOSED_CONFIGURATIONS:
            r = run_closed_loop(
                pool,
                policy,
                aware,
                ADAPTIVE_DEFAULTS if adaptive else None,
                clients,
                think_s,
            )
            policies[r["policy"]] = r
        points.append({"clients": float(clients), "policies": policies})
    return points


def fmt_num(x):
    """Mirror util::json::write_num (rust f64 Display: no exponent)."""
    if isinstance(x, bool):
        return "true" if x else "false"
    if math.isnan(x) or math.isinf(x):
        return "null"
    if x == math.floor(x) and abs(x) < 9.0e15:
        return str(int(x))
    s = repr(float(x))
    if "e" not in s and "E" not in s:
        return s
    # Expand exponent notation the way rust's `{}` prints positionally.
    mant, exp = s.split("e")
    exp = int(exp)
    neg = mant.startswith("-")
    if neg:
        mant = mant[1:]
    if "." in mant:
        intpart, frac = mant.split(".")
    else:
        intpart, frac = mant, ""
    digits = intpart + frac
    point = len(intpart) + exp
    if point <= 0:
        out = "0." + "0" * (-point) + digits
    elif point >= len(digits):
        out = digits + "0" * (point - len(digits))
    else:
        out = digits[:point] + "." + digits[point:]
    return ("-" if neg else "") + out


def to_json_value(v, indent, depth):
    pad = " " * (indent * (depth + 1))
    close_pad = " " * (indent * depth)
    if isinstance(v, dict):
        if not v:
            return "{}"
        parts = []
        for k in sorted(v.keys()):  # BTreeMap order
            parts.append(f'{pad}"{k}": ' + to_json_value(v[k], indent, depth + 1))
        return "{\n" + ",\n".join(parts) + "\n" + close_pad + "}"
    if isinstance(v, list):
        if not v:
            return "[]"
        parts = [pad + to_json_value(x, indent, depth + 1) for x in v]
        return "[\n" + ",\n".join(parts) + "\n" + close_pad + "]"
    if isinstance(v, str):
        return '"' + v + '"'
    return fmt_num(v)


def write_json(path, root):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(to_json_value(root, 2, 0))
    print(f"wrote {path}")


def summarize_open(points, drift):
    hdr = (
        f"{'load':>6} {'policy':<14} {'goodput':>8} {'shed%':>6} {'p50ms':>8} "
        f"{'p99ms':>9} {'batch':>6} {'hedge%':>7} {'waste%':>7}"
    )
    print(hdr)
    print("-" * len(hdr))
    names = ["edge_only", "cloud_only", "cnmt", "cnmt+queue", "cnmt+adaptive"]
    for p in points:
        for name in names:
            r = p["policies"][name]
            print(
                f"{p['offered_rps']:>6.0f} {name:<14} {r['throughput_rps']:>8.1f} "
                f"{r['shed_rate'] * 100:>6.1f} {r['p50_s'] * 1e3:>8.1f} "
                f"{r['p99_s'] * 1e3:>9.1f} {r['mean_batch']:>6.2f} "
                f"{r['hedge_rate'] * 100:>7.1f} {r['wasted_frac'] * 100:>7.1f}"
            )
    print("\ndrift scenario (edge slows %.1fx at t=%.0fs, %s r/s offered):" % (
        drift["spec"]["factor"],
        drift["spec"]["start_s"],
        fmt_num(drift["offered_rps"]),
    ))
    for name in ["cnmt", "cnmt+queue", "cnmt+adaptive"]:
        r = drift["policies"][name]
        print(
            f"{'':>6} {name:<14} {r['throughput_rps']:>8.1f} "
            f"{r['shed_rate'] * 100:>6.1f} {r['p50_s'] * 1e3:>8.1f} "
            f"{r['p99_s'] * 1e3:>9.1f} {r['mean_batch']:>6.2f} "
            f"{r['hedge_rate'] * 100:>7.1f} {r['wasted_frac'] * 100:>7.1f}"
        )
    print(
        "\ndrift headline: static/adaptive p99 ratio = %.1fx"
        % drift["headline_p99_ratio"]
    )


def summarize_closed(points):
    hdr = (
        f"{'K':>4} {'policy':<14} {'goodput':>8} {'mean ms':>8} {'p50ms':>8} "
        f"{'p99ms':>9} {'batch':>6} {'hedge%':>7}"
    )
    print(hdr)
    print("-" * len(hdr))
    for p in points:
        for name in ["cloud_only", "cnmt+queue", "cnmt+adaptive"]:
            r = p["policies"][name]
            print(
                f"{int(p['clients']):>4} {name:<14} {r['throughput_rps']:>8.1f} "
                f"{r['mean_latency_s'] * 1e3:>8.1f} {r['p50_s'] * 1e3:>8.1f} "
                f"{r['p99_s'] * 1e3:>9.1f} {r['mean_batch']:>6.2f} "
                f"{r['hedge_rate'] * 100:>7.1f}"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--loads",
        default=None,
        help="comma-separated offered loads in r/s (mirrors cnmt --loads)",
    )
    ap.add_argument(
        "--requests",
        type=int,
        default=REQUESTS_PER_POINT,
        help="requests per sweep point (mirrors cnmt --load-requests)",
    )
    ap.add_argument(
        "--closed-loop",
        action="store_true",
        help="closed-loop sweep (mirrors cnmt --closed-loop)",
    )
    ap.add_argument(
        "--clients",
        default=None,
        help="comma-separated client counts (mirrors cnmt --clients)",
    )
    ap.add_argument(
        "--think-ms",
        type=float,
        default=0.0,
        help="per-client think time in ms (mirrors cnmt --think-ms)",
    )
    args = ap.parse_args()

    if args.closed_loop:
        clients = (
            [int(s) for s in args.clients.split(",")] if args.clients else None
        )
        think_s = args.think_ms / 1e3
        points = run_closed_sweep(clients, args.requests, think_s)
        root = {
            "seed": float(SEED),
            "requests_per_point": float(args.requests),
            "think_s": think_s,
            "points": points,
        }
        write_json(args.out or "reports/closed_loop.json", root)
        summarize_closed(points)
        return

    loads = [float(s) for s in args.loads.split(",")] if args.loads else LOADS_RPS
    points = run_sweep(loads, args.requests)
    drift = run_drift(SEED, args.requests)
    last = points[-1]["policies"]
    headline = last["cnmt"]["p99_s"] / last["cnmt+queue"]["p99_s"]
    root = {
        "workload": {
            "edge_plane": list(EDGE_PLANE),
            "cloud_plane": list(CLOUD_PLANE),
            "n2m_gamma": N2M_GAMMA,
            "n2m_delta": N2M_DELTA,
            "rtt_s": RTT_S,
            "mean_n": MEAN_N,
        },
        "seed": float(SEED),
        "requests_per_point": float(args.requests),
        "points": points,
        "drift": drift,
        "headline_p99_ratio": headline,
    }
    write_json(args.out or "reports/load_sweep.json", root)
    summarize_open(points, drift)
    print(f"\nheadline: blind/aware p99 ratio at max load = {headline:.1f}x")


if __name__ == "__main__":
    main()
