#!/usr/bin/env python3
"""Standalone mirror of `cnmt experiment load` (rust/src/experiments/load.rs).

Why this exists: the load-sweep report checked in under `reports/` must be
regenerable in environments that have no rust toolchain (and the sweep's
dynamics need a second, independent implementation to validate against).
This script re-implements, operation for operation, exactly what the rust
driver does:

  * `util::rng::Rng`            — xoshiro256** + splitmix64 seeding, the
                                  exponential / Box-Muller draws (with the
                                  cached spare normal);
  * `experiments::load`         — the synthetic workload constants and
                                  draw order;
  * `metrics::histogram`        — the geometric-bucket quantiles;
  * `scheduler::*`              — admission queue, capacity tracker,
                                  length-bucketed batcher (bounded
                                  lookahead), two-lane dispatcher;
  * `coordinator::router`       — eq. 1 with the expected-wait terms and
                                  the EWMA T_tx estimator + heartbeat;
  * `sim::harness::run_contended` and the report JSON layout (BTreeMap
                                  key order, rust f64 `Display` number
                                  formatting).

Keep this file in lockstep with the rust sources. When both toolchains are
available, `cnmt experiment load --out reports` and this script must agree
(bit-for-bit up to libm rounding).

Usage:
    python3 python/tools/load_sweep_mirror.py [--out reports/load_sweep.json]
"""

import argparse
import math
import os

MASK = (1 << 64) - 1

# ---------------------------------------------------------------- rng (util::rng)


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded via splitmix64 (mirror of util::rng::Rng)."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s
        self.spare_normal = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def exponential(self, lam):
        while True:
            u = self.f64()
            if u > 1e-300:
                break
        return -math.log(u) / lam

    def normal(self):
        if self.spare_normal is not None:
            z, self.spare_normal = self.spare_normal, None
            return z
        while True:
            u1 = self.f64()
            if u1 > 1e-300:
                break
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        a = 2.0 * math.pi * u2
        self.spare_normal = r * math.sin(a)
        return r * math.cos(a)

    def normal_ms(self, mean, std):
        return mean + std * self.normal()


# ---------------------------------------------------------------- histogram (metrics)


def _powi(base, exp):
    """compiler-rt __powidf2: square-and-multiply, matching f64::powi."""
    recip = exp < 0
    if recip:
        exp = -exp
    r = 1.0
    a = base
    b = exp
    while True:
        if b & 1:
            r *= a
        b //= 2
        if b == 0:
            break
        a *= a
    return 1.0 / r if recip else r


class Histogram:
    """Mirror of metrics::Histogram::latency() (1e-6..1e3, 100/decade)."""

    def __init__(self, floor=1e-6, ceil=1e3, per_decade=100):
        self.floor = floor
        self.growth = math.pow(10.0, 1.0 / per_decade)
        self.ln_growth = math.log(self.growth)
        n = int(math.ceil(math.log(ceil / floor) / self.ln_growth)) + 1
        self.counts = [0] * n
        self.total = 0
        self.underflow = 0
        self.sum = 0.0

    def record(self, x):
        self.total += 1
        self.sum += x
        if x < self.floor:
            self.underflow += 1
            return
        idx = int(math.log(x / self.floor) / self.ln_growth)
        self.counts[min(idx, len(self.counts) - 1)] += 1

    def quantile(self, q):
        if self.total == 0:
            return float("nan")
        target = math.ceil(min(max(q, 0.0), 1.0) * self.total)
        seen = self.underflow
        if seen >= target and self.underflow > 0:
            return self.floor
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return self.floor * _powi(self.growth, i + 1)
        return self.floor * _powi(self.growth, len(self.counts))


# ---------------------------------------------------------------- predictor


def texe_estimate(plane, n, m):
    an, am, b = plane
    return max(an * n + am * m + b, 0.0)


def n2m_predict(gamma, delta, n):
    return max(gamma * n + delta, 1.0)


class TtxEstimator:
    """Mirror of predictor::ttx::TtxEstimator."""

    def __init__(self, alpha):
        self.alpha = alpha
        self.estimate = None
        self.last_obs_time = float("-inf")
        self.count = 0

    def observe(self, now_s, rtt_s):
        rtt_s = max(rtt_s, 0.0)
        if self.estimate is None:
            self.estimate = rtt_s
        else:
            self.estimate = self.estimate + self.alpha * (rtt_s - self.estimate)
        self.last_obs_time = now_s
        self.count += 1

    def estimate_or(self, fallback):
        return fallback if self.estimate is None else self.estimate

    def is_stale(self, now_s, max_age_s):
        return self.count == 0 or now_s - self.last_obs_time > max_age_s


# ---------------------------------------------------------------- workload (experiments::load)

EDGE_PLANE = (1.2e-3, 3.0e-3, 6.0e-3)
CLOUD_PLANE = (0.22e-3, 0.55e-3, 26.0e-3)
N2M_GAMMA = 0.95
N2M_DELTA = 0.8
RTT_S = 0.042
MEAN_N = 17.0
M_NOISE_STD = 2.0
EXEC_NOISE_STD = 0.05
N_MAX = 62


def _round_half_away(x):
    """f64::round (half away from zero); python round() is banker's."""
    return math.floor(x + 0.5) if x >= 0.0 else math.ceil(x - 0.5)


class RequestTruth:
    __slots__ = ("n", "m_real", "arrival_s", "t_edge", "t_cloud", "t_tx", "rtt")

    def __init__(self, n, m_real, arrival_s, t_edge, t_cloud, t_tx, rtt):
        self.n = n
        self.m_real = m_real
        self.arrival_s = arrival_s
        self.t_edge = t_edge
        self.t_cloud = t_cloud
        self.t_tx = t_tx
        self.rtt = rtt


def synth_workload(seed, count, offered_rps):
    rng = Rng(seed)
    requests = []
    t = 0.0
    sum_m = 0.0
    for _ in range(count):
        t += rng.exponential(offered_rps)
        n = 1 + min(int(rng.exponential(1.0 / MEAN_N)), N_MAX - 1)
        m_mean = N2M_GAMMA * n + N2M_DELTA
        m = _round_half_away(m_mean + rng.normal_ms(0.0, M_NOISE_STD))
        m = int(min(max(m, 1.0), float(N_MAX)))
        noise_e = max(1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD), 0.2)
        noise_c = max(1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD), 0.2)
        requests.append(
            RequestTruth(
                n,
                m,
                t,
                texe_estimate(EDGE_PLANE, n, m) * noise_e,
                texe_estimate(CLOUD_PLANE, n, m) * noise_c,
                RTT_S,
                RTT_S,
            )
        )
        sum_m += m
    mean_m = sum_m / max(count, 1)
    return requests, mean_m


# ---------------------------------------------------------------- scheduler

EDGE, CLOUD = 0, 1
BUCKET_WIDTH = 8.0
MAX_BATCH = 8
LOOKAHEAD = 32
MAX_QUEUE_DEPTH = 512
EDGE_WORKERS = 1
CLOUD_WORKERS = 4
BATCH_RESIDUAL = 0.15
TTX_REFRESH_S = 60.0


class Lane:
    def __init__(self, workers):
        self.items = []  # of (id, payload, n, m_est, est_service_s, arrival_s, bucket)
        self.free_at = [0.0] * workers
        self.backlog_est_s = 0.0
        self.offered = 0
        self.admitted = 0
        self.rejected = 0
        self.peak_depth = 0

    def offer(self, rq):
        self.offered += 1
        if len(self.items) >= MAX_QUEUE_DEPTH:
            self.rejected += 1
            return False
        self.items.append(rq)
        self.admitted += 1
        self.peak_depth = max(self.peak_depth, len(self.items))
        self.backlog_est_s += max(rq[4], 0.0)
        return True

    def earliest_free(self):
        best_i, best_t = 0, self.free_at[0]
        for i in range(1, len(self.free_at)):
            if self.free_at[i] < best_t:
                best_i, best_t = i, self.free_at[i]
        return best_i, best_t

    def expected_wait_s(self, now_s):
        inflight = 0.0
        for t in self.free_at:
            if t > now_s:
                inflight += t - now_s
        return (inflight + self.backlog_est_s) / len(self.free_at)


def form_batch(lane, start_s):
    items = lane.items
    head = items.pop(0)
    bucket = head[6]
    batch = [head]
    i = 0
    scanned = 0
    while len(batch) < MAX_BATCH and scanned < LOOKAHEAD:
        if i >= len(items):
            break
        rq = items[i]
        if rq[6] == bucket and rq[5] <= start_s:
            batch.append(rq)
            del items[i]
        else:
            i += 1
        scanned += 1
    return batch


def drain_lane(lane, device, horizon_s, requests, record, batch_stats):
    while lane.items:
        head_arrival = lane.items[0][5]
        worker, free_s = lane.earliest_free()
        start_s = max(free_s, head_arrival)
        if start_s > horizon_s:
            return
        batch = form_batch(lane, start_s)
        est_sum = 0.0
        mx = 0.0
        sm = 0.0
        for rq in batch:
            est_sum += rq[4]
            truth = requests[rq[1]]
            t = truth.t_edge if device == EDGE else truth.t_cloud
            if t > mx:
                mx = t
            sm += t
        service_s = max(mx + (sm - mx) * BATCH_RESIDUAL, 0.0)
        done_s = start_s + service_s
        lane.backlog_est_s = max(lane.backlog_est_s - est_sum, 0.0)
        lane.free_at[worker] = done_s
        batch_stats[0] += 1
        batch_stats[1] += len(batch)
        for rq in batch:
            record(rq, device, done_s)


# ---------------------------------------------------------------- router + run_contended

EDGE_ONLY, CLOUD_ONLY, CNMT = "edge_only", "cloud_only", "cnmt"


def run_contended(requests, mean_m, policy, queue_aware):
    ttx = TtxEstimator(0.3)
    ttx_prior = 0.05
    lanes = [Lane(EDGE_WORKERS), Lane(CLOUD_WORKERS)]
    hist = Histogram()
    # OnlineStats mean via Welford, as in metrics::stats.
    stats_count = 0
    stats_mean = 0.0
    counts = [0, 0]
    completed = [0]
    last_done = [0.0]
    batch_stats = [0, 0]

    def record(rq, device, done_s):
        nonlocal stats_count, stats_mean
        truth = requests[rq[1]]
        tx_s = truth.t_tx if device == CLOUD else 0.0
        latency = (done_s - rq[5]) + tx_s
        hist.record(latency)
        stats_count += 1
        stats_mean += (latency - stats_mean) / stats_count
        counts[device] += 1
        completed[0] += 1
        if done_s + tx_s > last_done[0]:
            last_done[0] = done_s + tx_s

    rejected = 0
    for i, truth in enumerate(requests):
        now = truth.arrival_s
        for d in (EDGE, CLOUD):
            drain_lane(lanes[d], d, now, requests, record, batch_stats)
        if ttx.is_stale(now, TTX_REFRESH_S):
            ttx.observe(now, truth.rtt)
        if queue_aware:
            edge_wait = lanes[EDGE].expected_wait_s(now)
            cloud_wait = lanes[CLOUD].expected_wait_s(now)
        else:
            edge_wait = cloud_wait = 0.0
        ttx_est = ttx.estimate_or(ttx_prior)
        if policy == EDGE_ONLY:
            device = EDGE
        elif policy == CLOUD_ONLY:
            device = CLOUD
        else:
            m_est_r = n2m_predict(N2M_GAMMA, N2M_DELTA, truth.n)
            t_e = texe_estimate(EDGE_PLANE, truth.n, m_est_r)
            t_c = texe_estimate(CLOUD_PLANE, truth.n, m_est_r)
            device = EDGE if t_e + edge_wait <= ttx_est + t_c + cloud_wait else CLOUD
        if device == CLOUD:
            ttx.observe(now, truth.rtt)
        m_est = n2m_predict(N2M_GAMMA, N2M_DELTA, truth.n)
        plane = EDGE_PLANE if device == EDGE else CLOUD_PLANE
        est_service = texe_estimate(plane, truth.n, m_est)
        bucket = int(max(m_est, 0.0) / BUCKET_WIDTH)
        rq = (i, i, truth.n, m_est, est_service, now, bucket)
        if not lanes[device].offer(rq):
            rejected += 1
    for d in (EDGE, CLOUD):
        drain_lane(lanes[d], d, float("inf"), requests, record, batch_stats)

    first_arrival = requests[0].arrival_s if requests else 0.0
    makespan = max(last_done[0] - first_arrival, 0.0)
    mean_batch = (
        batch_stats[1] / batch_stats[0] if batch_stats[0] else float("nan")
    )
    return {
        "policy": policy + ("+queue" if queue_aware else ""),
        "queue_aware": queue_aware,
        "offered": float(len(requests)),
        "completed": float(completed[0]),
        "rejected": float(rejected),
        "shed_rate": (rejected / len(requests)) if requests else 0.0,
        "edge_count": float(counts[EDGE]),
        "cloud_count": float(counts[CLOUD]),
        "makespan_s": makespan,
        "throughput_rps": completed[0] / makespan if makespan > 0.0 else 0.0,
        "mean_latency_s": stats_mean if stats_count else float("nan"),
        "p50_s": hist.quantile(0.50),
        "p95_s": hist.quantile(0.95),
        "p99_s": hist.quantile(0.99),
        "mean_batch": mean_batch,
        "edge_peak_depth": float(lanes[EDGE].peak_depth),
        "cloud_peak_depth": float(lanes[CLOUD].peak_depth),
    }


# ---------------------------------------------------------------- sweep + json

SEED = 20220315
REQUESTS_PER_POINT = 20000
LOADS_RPS = [4.0, 8.0, 16.0, 32.0, 64.0, 96.0, 128.0]
CONFIGURATIONS = [
    (EDGE_ONLY, False),
    (CLOUD_ONLY, False),
    (CNMT, False),
    (CNMT, True),
]


def run_sweep(loads_rps=None, requests_per_point=None):
    loads_rps = LOADS_RPS if loads_rps is None else loads_rps
    requests_per_point = (
        REQUESTS_PER_POINT if requests_per_point is None else requests_per_point
    )
    points = []
    for i, load in enumerate(loads_rps):
        seed = SEED ^ (((i + 1) * 0x9E3779B97F4A7C15) & MASK)
        requests, mean_m = synth_workload(seed, requests_per_point, load)
        policies = {}
        for policy, aware in CONFIGURATIONS:
            r = run_contended(requests, mean_m, policy, aware)
            policies[r["policy"]] = r
        points.append({"offered_rps": load, "policies": policies})
    return points


def fmt_num(x):
    """Mirror util::json::write_num (rust f64 Display: no exponent)."""
    if isinstance(x, bool):
        return "true" if x else "false"
    if math.isnan(x) or math.isinf(x):
        return "null"
    if x == math.floor(x) and abs(x) < 9.0e15:
        return str(int(x))
    s = repr(float(x))
    if "e" not in s and "E" not in s:
        return s
    # Expand exponent notation the way rust's `{}` prints positionally.
    mant, exp = s.split("e")
    exp = int(exp)
    neg = mant.startswith("-")
    if neg:
        mant = mant[1:]
    if "." in mant:
        intpart, frac = mant.split(".")
    else:
        intpart, frac = mant, ""
    digits = intpart + frac
    point = len(intpart) + exp
    if point <= 0:
        out = "0." + "0" * (-point) + digits
    elif point >= len(digits):
        out = digits + "0" * (point - len(digits))
    else:
        out = digits[:point] + "." + digits[point:]
    return ("-" if neg else "") + out


def to_json_value(v, indent, depth):
    pad = " " * (indent * (depth + 1))
    close_pad = " " * (indent * depth)
    if isinstance(v, dict):
        if not v:
            return "{}"
        parts = []
        for k in sorted(v.keys()):  # BTreeMap order
            parts.append(f'{pad}"{k}": ' + to_json_value(v[k], indent, depth + 1))
        return "{\n" + ",\n".join(parts) + "\n" + close_pad + "}"
    if isinstance(v, list):
        if not v:
            return "[]"
        parts = [pad + to_json_value(x, indent, depth + 1) for x in v]
        return "[\n" + ",\n".join(parts) + "\n" + close_pad + "]"
    if isinstance(v, str):
        return '"' + v + '"'
    return fmt_num(v)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="reports/load_sweep.json")
    ap.add_argument(
        "--loads",
        default=None,
        help="comma-separated offered loads in r/s (mirrors cnmt --loads)",
    )
    ap.add_argument(
        "--requests",
        type=int,
        default=REQUESTS_PER_POINT,
        help="requests per sweep point (mirrors cnmt --load-requests)",
    )
    args = ap.parse_args()
    loads = (
        [float(s) for s in args.loads.split(",")] if args.loads else LOADS_RPS
    )

    points = run_sweep(loads, args.requests)
    last = points[-1]["policies"]
    headline = last["cnmt"]["p99_s"] / last["cnmt+queue"]["p99_s"]

    root = {
        "workload": {
            "edge_plane": list(EDGE_PLANE),
            "cloud_plane": list(CLOUD_PLANE),
            "n2m_gamma": N2M_GAMMA,
            "n2m_delta": N2M_DELTA,
            "rtt_s": RTT_S,
            "mean_n": MEAN_N,
        },
        "seed": float(SEED),
        "requests_per_point": float(args.requests),
        "points": points,
        "headline_p99_ratio": headline,
    }

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(to_json_value(root, 2, 0))
    print(f"wrote {args.out}")

    # Human-readable summary (matches load::render_text's columns).
    hdr = f"{'load':>6} {'policy':<12} {'goodput':>8} {'shed%':>6} {'p50ms':>8} {'p99ms':>9} {'batch':>6}"
    print(hdr)
    print("-" * len(hdr))
    for p in points:
        for name in ("edge_only", "cloud_only", "cnmt", "cnmt+queue"):
            r = p["policies"][name]
            print(
                f"{p['offered_rps']:>6.0f} {name:<12} {r['throughput_rps']:>8.1f} "
                f"{r['shed_rate'] * 100:>6.1f} {r['p50_s'] * 1e3:>8.1f} "
                f"{r['p99_s'] * 1e3:>9.1f} {r['mean_batch']:>6.2f}"
            )
    print(f"\nheadline: blind/aware p99 ratio at max load = {headline:.1f}x")


if __name__ == "__main__":
    main()
