#!/usr/bin/env python3
"""Scheduler-core differential check + bench seed: pre-change vs dense.

The build container that authored the zero-churn scheduler PR has no
rust toolchain, so the checked-in `reports/BENCH_sched.json` cannot be
produced by `cnmt bench sched --json` here. This script seeds that file
from the lockstep python mirror instead, and its primary output is the
**equivalence proof**, not the timings:

  * **baseline** — a frozen copy of the pre-change mirror dispatcher
    (id-keyed hedge dict + cancel-token set, fresh lists per batch),
    exactly as previously checked in;
  * **dense**    — the current mirror dispatcher imported from
    `load_sweep_mirror.py` (slab-style arena with free-list recycling,
    cancellation as a state flag in the race entry).

Both replay the *identical* pre-generated request stream (solo + hedged
mix at a load that keeps queues deep), and the script asserts their
outputs are float-identical (completion count, completion-time
checksum, hedge counters) before timing them — a second, independent
confirmation that the rewrite changed data structures, not behaviour.

The python timings are reported for completeness but are
**interpreter-bound and not representative** of the rust change
(python allocates boxed objects and hashes small ints regardless of the
container used, so the rust rewrite's allocation/hashing elimination is
invisible here — the two implementations measure within ~15% of each
other either way). The measurement of record for the ≥2x events/sec
target is `cnmt bench sched --json`, which drives the same stream
through the dense dispatcher and the frozen rust baseline
(`scheduler::baseline`) in one binary; the CI `bench` job regenerates
this report rust-natively on every push and gates on its floors.

`events` counts dispatcher events processed: batch starts + completion
events — the same definition `cnmt bench sched` uses, so the two
producers are comparable.

Usage:
    python3 python/tools/bench_sched_mirror.py \
        [--requests 40000] [--out reports/BENCH_sched.json]
"""

import argparse
import heapq
import importlib.util
import json
import math
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))


def _load_mirror():
    spec = importlib.util.spec_from_file_location(
        "load_sweep_mirror", os.path.join(HERE, "load_sweep_mirror.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


dense = _load_mirror()

EDGE, CLOUD = 0, 1
SOLO, WIN, LOSS = 0, 1, 2
QUEUED, RUNNING, DONE = 0, 1, 2
MAX_QUEUE_DEPTH = dense.MAX_QUEUE_DEPTH
MAX_BATCH = dense.MAX_BATCH
LOOKAHEAD = dense.LOOKAHEAD
EDGE_WORKERS = dense.EDGE_WORKERS
CLOUD_WORKERS = dense.CLOUD_WORKERS


# ------------------------------------------------------------------
# Frozen pre-change dispatcher (the mirror as previously checked in:
# list queues with pop(0)/del, hedges dict keyed by request id, cancel
# tokens in a side set). Kept verbatim so the baseline is the actual
# pre-PR implementation, not a strawman.
# ------------------------------------------------------------------


class BaselineLane:
    def __init__(self, workers):
        self.items = []
        self.free_at = [0.0] * workers
        self.backlog_est_s = 0.0
        self.dead = 0
        self.offered = 0
        self.admitted = 0
        self.rejected = 0
        self.peak_depth = 0

    def offer(self, rq):
        self.offered += 1
        if len(self.items) - self.dead >= MAX_QUEUE_DEPTH:
            self.rejected += 1
            return False
        self.items.append(rq)
        self.admitted += 1
        self.peak_depth = max(self.peak_depth, len(self.items) - self.dead)
        self.backlog_est_s += max(rq[4], 0.0)
        return True

    def earliest_free(self):
        best_i, best_t = 0, self.free_at[0]
        for i in range(1, len(self.free_at)):
            if self.free_at[i] < best_t:
                best_i, best_t = i, self.free_at[i]
        return best_i, best_t

    def expected_wait_s(self, now_s):
        inflight = 0.0
        for t in self.free_at:
            if t > now_s:
                inflight += t - now_s
        return (inflight + self.backlog_est_s) / len(self.free_at)

    def on_cancel(self, est):
        self.backlog_est_s = max(self.backlog_est_s - max(est, 0.0), 0.0)


class BaselineDispatcher:
    def __init__(self):
        self.lanes = [BaselineLane(EDGE_WORKERS), BaselineLane(CLOUD_WORKERS)]
        self.batches = 0
        self.batch_requests = 0
        self.pending = []
        self.seq = 0
        self.hedges = {}
        self.cancelled = set()
        self.hs_hedged = 0
        self.hs_wins = [0, 0]
        self.hs_cancelled = 0
        self.hs_losers = 0

    def submit(self, device, rq):
        return self.lanes[device].offer(rq)

    def submit_hedged(self, rq, est_edge, est_cloud):
        edge_rq = rq[:4] + (est_edge,) + rq[5:]
        cloud_rq = rq[:4] + (est_cloud,) + rq[5:]
        edge_ok = self.lanes[EDGE].offer(edge_rq)
        cloud_ok = self.lanes[CLOUD].offer(cloud_rq)
        if edge_ok and cloud_ok:
            self.hs_hedged += 1
            self.hedges[rq[0]] = [est_edge, est_cloud, QUEUED, QUEUED, None]
            return "hedged"
        if edge_ok:
            return "single_edge"
        if cloud_ok:
            return "single_cloud"
        return "rejected"

    def lane_next_start(self, device):
        lane = self.lanes[device]
        while True:
            if not lane.items:
                return None
            head = lane.items[0]
            if head[0] in self.cancelled:
                lane.items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
                self.cancelled.discard(head[0])
                continue
            _w, free_s = lane.earliest_free()
            return max(free_s, head[5])

    def next_batch_start(self):
        e = self.lane_next_start(EDGE)
        c = self.lane_next_start(CLOUD)
        if e is None and c is None:
            return None
        if c is None or (e is not None and e <= c):
            return (EDGE, e)
        return (CLOUD, c)

    def form_batch(self, lane, start_s):
        items = lane.items
        while True:
            if not items:
                return []
            if items[0][0] in self.cancelled:
                self.cancelled.discard(items[0][0])
                items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
            else:
                break
        head = items.pop(0)
        bucket = head[6]
        batch = [head]
        i = 0
        scanned = 0
        while len(batch) < MAX_BATCH and scanned < LOOKAHEAD:
            if i >= len(items):
                break
            rq = items[i]
            if rq[0] in self.cancelled:
                del items[i]
                lane.dead = max(lane.dead - 1, 0)
                self.cancelled.discard(rq[0])
                continue
            if rq[6] == bucket and rq[5] <= start_s:
                batch.append(rq)
                del items[i]
            else:
                i += 1
            scanned += 1
        return batch

    def dispatch_at(self, device, start_s, exec_fn):
        lane = self.lanes[device]
        batch = self.form_batch(lane, start_s)
        if not batch:
            return
        for rq in batch:
            h = self.hedges.get(rq[0])
            if h is not None:
                h[2 + device] = RUNNING
        est_sum = 0.0
        for rq in batch:
            est_sum += rq[4]
        service_s = max(exec_fn(device, batch, start_s), 0.0)
        done_s = start_s + service_s
        worker, _free = lane.earliest_free()
        lane.backlog_est_s = max(lane.backlog_est_s - est_sum, 0.0)
        lane.free_at[worker] = done_s
        self.batches += 1
        self.batch_requests += len(batch)
        bsize = len(batch)
        for rq in batch:
            heapq.heappush(
                self.pending, (done_s, self.seq, start_s, bsize, device, rq)
            )
            self.seq += 1

    def resolve_completion(self, device, rq_id):
        h = self.hedges.get(rq_id)
        if h is None:
            return SOLO
        h[2 + device] = DONE
        if h[4] is not None:
            del self.hedges[rq_id]
            self.hs_losers += 1
            return LOSS
        h[4] = device
        self.hs_wins[device] += 1
        twin = 1 - device
        if h[2 + twin] == QUEUED:
            self.cancelled.add(rq_id)
            self.hs_cancelled += 1
            self.lanes[twin].on_cancel(h[twin])
            self.lanes[twin].dead += 1
            del self.hedges[rq_id]
        return WIN

    def flush_one(self, out):
        done_s, _seq, start_s, bsize, device, rq = heapq.heappop(self.pending)
        kind = self.resolve_completion(device, rq[0])
        out.append((rq, device, start_s, done_s, bsize, kind))

    def step(self, horizon_s, exec_fn, out):
        ns = self.next_batch_start()
        nd = self.pending[0][0] if self.pending else None
        if ns is None and nd is None:
            return False
        completion_first = ns is None or (nd is not None and nd <= ns[1])
        if completion_first:
            if nd > horizon_s:
                return False
            self.flush_one(out)
        else:
            device, start_s = ns
            if start_s > horizon_s:
                return False
            self.dispatch_at(device, start_s, exec_fn)
        return True

    def run_until(self, horizon_s, exec_fn, out):
        while self.step(horizon_s, exec_fn, out):
            pass


# ------------------------------------------------------------------
# Shared driver: identical pre-generated stream through either
# implementation.
# ------------------------------------------------------------------


def gen_stream(requests, offered_rps, hedge_every, seed=0xBE7C5):
    """Pre-generate (truth, device, hedge, ests, bucket) per request so
    the timed loop does no RNG or model work — it measures the
    dispatcher, not the workload generator."""
    pool = dense.synth_workload(seed, requests, offered_rps)
    stream = []
    for i, truth in enumerate(pool):
        m_est = dense.n2m_predict(dense.N2M_GAMMA, dense.N2M_DELTA, truth.n)
        est_e = dense.texe_estimate(dense.EDGE_PLANE, truth.n, m_est)
        est_c = dense.texe_estimate(dense.CLOUD_PLANE, truth.n, m_est)
        bucket = int(max(m_est, 0.0) / dense.BUCKET_WIDTH)
        hedged = hedge_every > 0 and i % hedge_every == 0
        device = EDGE if i % 3 == 0 else CLOUD
        stream.append(
            (truth.arrival_s, truth.n, m_est, est_e, est_c, bucket, hedged, device)
        )
    return pool, stream


def drive(disp, pool, stream, tuple_extra):
    """Replay the stream; returns (events, wall_s, fingerprint)."""

    def exec_fn(device, batch, start_s):
        mx = 0.0
        sm = 0.0
        for rq in batch:
            truth = pool[rq[1]]
            t = truth.t_edge if device == EDGE else truth.t_cloud
            if t > mx:
                mx = t
            sm += t
        return mx + (sm - mx) * dense.BATCH_RESIDUAL

    out = []
    completions = [0]
    checksum = [0.0]
    results = [0]

    t0 = time.perf_counter()
    for i, (arrival, n, m_est, est_e, est_c, bucket, hedged, device) in enumerate(
        stream
    ):
        out.clear()
        disp.run_until(arrival, exec_fn, out)
        for comp in out:
            completions[0] += 1
            checksum[0] += comp[3]
            if comp[5] != LOSS:
                results[0] += 1
        if hedged:
            rq = (i, i, n, m_est, 0.0, arrival, bucket) + tuple_extra
            disp.submit_hedged(rq, est_e, est_c)
        else:
            est = est_e if device == EDGE else est_c
            rq = (i, i, n, m_est, est, arrival, bucket) + tuple_extra
            disp.submit(device, rq)
    out.clear()
    disp.run_until(float("inf"), exec_fn, out)
    for comp in out:
        completions[0] += 1
        checksum[0] += comp[3]
        if comp[5] != LOSS:
            results[0] += 1
    wall_s = time.perf_counter() - t0

    events = completions[0] + disp.batches
    fingerprint = {
        "completions": completions[0],
        "results": results[0],
        "batches": disp.batches,
        "done_s_checksum": checksum[0],
        "hedged": disp.hs_hedged,
        "cancelled": disp.hs_cancelled,
        "wasted": disp.hs_losers,
    }
    return events, wall_s, fingerprint


def measure(requests, offered_rps, hedge_every, repeats=3):
    pool, stream = gen_stream(requests, offered_rps, hedge_every)
    best = {}
    fingerprints = {}
    for name, mk, extra in (
        ("baseline", BaselineDispatcher, ()),
        ("dense", dense.Dispatcher, (None,)),
    ):
        best_wall = math.inf
        events = None
        for _ in range(repeats):
            disp = mk()
            ev, wall, fp = drive(disp, pool, stream, extra)
            fingerprints[name] = fp
            best_wall = min(best_wall, wall)
            events = ev
        best[name] = (events, best_wall)
    # The rewrite must not change behaviour: identical event counts and
    # completion-time checksums, or the comparison is meaningless.
    fb, fd = fingerprints["baseline"], fingerprints["dense"]
    assert fb == fd, f"implementations diverged: {fb} vs {fd}"
    return best, fb


def section(events, wall_s, requests, offered_rps, hedge_every):
    eps = events / wall_s
    return {
        "requests": float(requests),
        "offered_rps": offered_rps,
        "hedge_every": float(hedge_every),
        "events": float(events),
        "wall_s": wall_s,
        "events_per_sec": eps,
        "ns_per_event": 1e9 / eps,
    }


def measure_scenario(requests, repeats=3):
    """Proxy for the rust bench's `scenario` section: the SLO-class
    replay (fair EDF front-end + class-aware hedging + batch-aware
    waits) vs the class-blind FIFO replay of the identical storm.
    Both sides run in the same interpreter, so — unlike the absolute
    timings — the *ratio* is a meaningful pay-for-use measure."""
    import sys as _sys

    _sys.path.insert(0, HERE)
    import scenario_mirror as sm

    spec = sm.default_spec()
    spec["requests"] = requests
    topo = sm.topo_preset(spec["topology"])
    stream = sm.synth_shaped_workload(spec["seed"], spec["requests"], spec["load"])
    out = {}
    for tag, variant in (
        ("fifo", sm.baseline_variant(spec)),
        ("edf", sm.treatment_variant(spec)),
    ):
        best_wall = math.inf
        completed = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = sm.run_scenario_engine(stream, topo, variant)
            best_wall = min(best_wall, time.perf_counter() - t0)
            completed = res["completed"]
        rps = requests / best_wall
        out[tag] = {
            "scheduling": tag,
            "requests": float(requests),
            "completed": completed,
            "wall_s": best_wall,
            "requests_per_sec": rps,
        }
    out["ratio"] = (
        out["edf"]["requests_per_sec"] / out["fifo"]["requests_per_sec"]
    )
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=40_000)
    ap.add_argument("--scenario-requests", type=int, default=10_000)
    ap.add_argument("--out", default="reports/BENCH_sched.json")
    args = ap.parse_args()

    scenarios = {
        # Deep-backlog regime (offered >> drain rate): the head-purge /
        # mid-queue-removal churn the rewrite eliminates is on the
        # critical path here. hedge_every = 0 disables hedging.
        "event_loop_solo": (args.requests, 320.0, 0),
        # Heavy hedging: arena/cancel/purge bookkeeping on every 3rd
        # request, same deep-backlog regime.
        "event_loop_hedged": (args.requests, 320.0, 3),
    }
    root = {
        "schema": "bench_sched/v1",
        "producer": "python/tools/bench_sched_mirror.py",
        "python_proxy": True,
        "note": (
            "Seeded from the python mirror: the authoring container has no "
            "rust toolchain. The equivalence fingerprints are the load-"
            "bearing content (pre-change vs dense dispatcher, identical "
            "behaviour on identical streams); the python timings are "
            "interpreter-bound and NOT representative of the rust "
            "data-structure change. The measurement of record is `cnmt "
            "bench sched --json` (dense vs the frozen rust baseline in "
            "scheduler::baseline, same binary, same container), which the "
            "CI `bench` job regenerates and gates on every push — flip "
            "this file's provenance to that producer on the first "
            "toolchain-equipped session (see ROADMAP)."
        ),
        "baseline": {
            "structures": (
                "pre-change dispatcher: id-keyed hedge dict, cancel-token "
                "set, per-batch list churn"
            )
        },
        "python_speedup_not_representative": {},
        "equivalence": {},
    }
    for name, (requests, rps, hedge_every) in scenarios.items():
        best, fp = measure(requests, rps, hedge_every)
        ev_b, wall_b = best["baseline"]
        ev_d, wall_d = best["dense"]
        root[name] = section(ev_d, wall_d, requests, rps, hedge_every)
        root["baseline"][name] = section(ev_b, wall_b, requests, rps, hedge_every)
        root["python_speedup_not_representative"][name] = (ev_d / wall_d) / (
            ev_b / wall_b
        )
        root["equivalence"][name] = dict(
            {k: float(v) for k, v in fp.items() if k != "done_s_checksum"},
            identical=True,
        )
        print(
            f"{name}: baseline {ev_b / wall_b:,.0f} ev/s → dense "
            f"{ev_d / wall_d:,.0f} ev/s  (python proxy; behaviour identical, "
            f"{fp['hedged']} hedges, {fp['cancelled']} cancels)"
        )

    scenario = measure_scenario(args.scenario_requests)
    root["scenario"] = scenario
    print(
        f"scenario: fifo {scenario['fifo']['requests_per_sec']:,.0f} req/s → "
        f"edf {scenario['edf']['requests_per_sec']:,.0f} req/s  "
        f"({scenario['ratio']:.2f}x; python proxy, ratio is the signal)"
    )

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(root, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
