#!/usr/bin/env python3
"""Standalone mirror of `cnmt experiment detect` (rust/src/experiments/detect.rs).

The detection-quality evaluation: five scenarios replay the outage pool
on the `hetero` fleet — failover armed, telemetry sampling on, the
online anomaly detector attached — and the alert stream is scored
against the injected ground truth:

  * `twin`  — fault-free. Zero alerts is an invariant, not a score.
  * `crash` — the checked-in outage fault (lead edge gateway down 30 s).
  * `slow`  — the same lane fail-slows x4 (execution-residual CUSUM).
  * `link`  — the first cloud replica's transfer degrades x8.
  * `surge` — post-onset arrivals compressed x2.5 (multi-lane gauge
    breach, blamed on no single device).

This file re-implements the rust detector, blame ledger and experiment
driver float-for-float — keep it in lockstep with `obs::detect`,
`obs::attribute` and `experiments::detect`. The CI `detect` matrix row
diffs the two implementations at smoke and full parameters.

Usage:
    python3 python/tools/detect_mirror.py [--out reports/detect_eval.json]
    python3 python/tools/detect_mirror.py --requests 2000
    python3 python/tools/detect_mirror.py --off-check   # observation-only proof
"""

import argparse
import math
import os
import struct
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from fleet_sweep_mirror import (  # noqa: E402
    CLOUD,
    cell_seed,
    topo_hetero,
    topo_to_json,
)
from load_sweep_mirror import (  # noqa: E402
    SEED,
    RequestTruth,
    synth_workload,
    to_json_value,
    write_json,
)
from outage_mirror import (  # noqa: E402
    GOODPUT_WINDOW_S,
    OUTAGE_OFFERED_RPS,
    OUTAGE_REQUESTS,
    OUTAGE_SEED_TAG,
    RETRY_POLICY,
    TELEMETRY_CFG,
    OutageRun,
    fault_to_json,
    outage_fault_spec,
)

# experiments::detect constants.
SLOW_FACTOR = 4.0
LINK_FACTOR = 8.0
SURGE_RATE = 2.5
SCENARIOS = ["twin", "crash", "slow", "link", "surge"]

# DetectCfg defaults (mirror of obs::DetectCfg::default).
DETECT_CFG = {
    "warmup": 64,
    "cusum_k": 3.0,
    "cusum_h": 25.0,
    "sigma_floor": 0.25,
    "clear_after": 8,
    "gauge_warmup": 8,
    "gauge_lambda": 0.25,
    "gauge_l": 8.0,
    "surge_lanes": 2,
    "surge_clear": 3,
}

# Gauge sigma floors (obs::detect::DEPTH_FLOOR / WAIT_FLOOR).
DEPTH_FLOOR = 1.0
WAIT_FLOOR = 0.05

# AlertKind tags (obs::event::AlertKind::tag).
DEVICE_SLOWDOWN = "device_slowdown"
LINK_DEGRADATION = "link_degradation"
DEVICE_CRASH = "device_crash"
LOAD_SURGE = "load_surge"

SURGE_NONE = 2**32 - 1  # u32::MAX lane sentinel


class Chart:
    """One-sided CUSUM chart over standardized log residuals (mirror of
    obs::detect::Chart)."""

    __slots__ = ("seen", "mean", "m2", "mu", "sigma", "s", "calm", "alerted")

    def __init__(self):
        self.seen = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.mu = 0.0
        self.sigma = 0.0
        self.s = 0.0
        self.calm = 0
        self.alerted = False

    def observe(self, x, cfg):
        """Returns None, ("raise", score) or ("clear",)."""
        self.seen += 1
        if self.seen <= cfg["warmup"]:
            d = x - self.mean
            self.mean += d / self.seen
            self.m2 += d * (x - self.mean)
            if self.seen == cfg["warmup"]:
                self.mu = self.mean
                var = self.m2 / max(cfg["warmup"] - 1, 1)
                self.sigma = max(math.sqrt(var), cfg["sigma_floor"])
            return None
        z = (x - self.mu) / self.sigma
        self.s = max(self.s + z - cfg["cusum_k"], 0.0)
        if not self.alerted:
            if self.s > cfg["cusum_h"]:
                self.alerted = True
                self.calm = 0
                return ("raise", self.s)
        elif z <= cfg["cusum_k"]:
            self.calm += 1
            if self.calm >= cfg["clear_after"]:
                self.alerted = False
                self.s = 0.0
                self.calm = 0
                return ("clear",)
        else:
            self.calm = 0
        return None


class Gauge:
    """EWMA control chart over one gauge stream (mirror of
    obs::detect::Gauge)."""

    __slots__ = ("floor", "seen", "mean", "m2", "limit", "z")

    def __init__(self, floor):
        self.floor = floor
        self.seen = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.limit = float("inf")
        self.z = 0.0

    def observe(self, x, cfg):
        self.seen += 1
        if self.seen <= cfg["gauge_warmup"]:
            d = x - self.mean
            self.mean += d / self.seen
            self.m2 += d * (x - self.mean)
            if self.seen == cfg["gauge_warmup"]:
                var = self.m2 / max(cfg["gauge_warmup"] - 1, 1)
                sigma = max(math.sqrt(var), self.floor)
                sigma_z = sigma * math.sqrt(
                    cfg["gauge_lambda"] / (2.0 - cfg["gauge_lambda"])
                )
                self.limit = self.mean + cfg["gauge_l"] * sigma_z
                self.z = self.mean
            return False
        self.z = cfg["gauge_lambda"] * x + (1.0 - cfg["gauge_lambda"]) * self.z
        return self.z > self.limit


class Detector:
    """Mirror of obs::detect::Detector — see that module's docs for the
    alert taxonomy and the collateral-absorption rules."""

    def __init__(self, tiers, cfg):
        n = len(tiers)
        self.cfg = cfg
        self.cloud = [t == CLOUD for t in tiers]
        self.exec = [Chart() for _ in range(n)]
        self.tx = [Chart() for _ in range(n)]
        self.depth = [Gauge(DEPTH_FLOOR) for _ in range(n)]
        self.wait = [Gauge(WAIT_FLOOR) for _ in range(n)]
        self.crash_active = [False] * n
        self.device_alerts = 0
        self.surge_active = False
        self.surge_blocked = False
        self.surge_breach = 0
        self.surge_first = SURGE_NONE
        self.surge_calm = 0
        self.log = []  # {t_s, lane, kind, score, raised} in detection order
        self.raised = 0
        self.cleared = 0
        self.timeouts_seen = 0
        self.reroutes_seen = 0

    def emit(self, t_s, lane, kind, score, raised):
        if raised:
            self.raised += 1
        else:
            self.cleared += 1
        self.log.append(
            {"t_s": t_s, "lane": lane, "kind": kind, "score": score, "raised": raised}
        )

    def other_device_alert(self, lane):
        own = (
            int(self.exec[lane].alerted)
            + int(self.tx[lane].alerted)
            + int(self.crash_active[lane])
        )
        return self.device_alerts > own

    def device_alert_cleared(self):
        self.device_alerts -= 1
        self.surge_blocked = True

    def observe_exec(self, lane, t_s, obs_s, est_s):
        if self.crash_active[lane]:
            self.crash_active[lane] = False
            self.emit(t_s, lane, DEVICE_CRASH, 0.0, False)
            self.device_alert_cleared()
        if not (obs_s > 0.0 and est_s > 0.0) or self.other_device_alert(lane):
            return
        x = math.log(obs_s / est_s)
        step = self.exec[lane].observe(x, self.cfg)
        if step is not None:
            if step[0] == "raise":
                self.device_alerts += 1
                self.emit(t_s, lane, DEVICE_SLOWDOWN, step[1], True)
            else:
                self.emit(t_s, lane, DEVICE_SLOWDOWN, 0.0, False)
                self.device_alert_cleared()

    def observe_tx(self, lane, t_s, tx_s, tokens):
        if (
            not self.cloud[lane]
            or not (tx_s > 0.0 and tokens > 0.0)
            or self.other_device_alert(lane)
        ):
            return
        x = math.log(tx_s / tokens)
        step = self.tx[lane].observe(x, self.cfg)
        if step is not None:
            if step[0] == "raise":
                self.device_alerts += 1
                self.emit(t_s, lane, LINK_DEGRADATION, step[1], True)
            else:
                self.emit(t_s, lane, LINK_DEGRADATION, 0.0, False)
                self.device_alert_cleared()

    def observe_reroute(self, lane, t_s):
        self.reroutes_seen += 1
        if not self.crash_active[lane]:
            self.crash_active[lane] = True
            self.device_alerts += 1
            self.emit(t_s, lane, DEVICE_CRASH, 1.0, True)

    def observe_timeout(self, _t_s):
        self.timeouts_seen += 1

    def observe_gauge(self, lane, depth, wait_s):
        d = self.depth[lane].observe(depth, self.cfg)
        w = self.wait[lane].observe(wait_s, self.cfg)
        if d or w:
            self.surge_breach += 1
            if lane < self.surge_first:
                self.surge_first = lane

    def commit_sample(self, t_s):
        breach = self.surge_breach
        first = self.surge_first
        self.surge_breach = 0
        self.surge_first = SURGE_NONE
        if self.surge_active:
            if breach == 0:
                self.surge_calm += 1
                if self.surge_calm >= self.cfg["surge_clear"]:
                    self.surge_active = False
                    self.surge_calm = 0
                    self.emit(t_s, 0, LOAD_SURGE, 0.0, False)
            else:
                self.surge_calm = 0
            return
        if breach == 0:
            self.surge_blocked = False
            return
        if (
            breach >= self.cfg["surge_lanes"]
            and self.device_alerts == 0
            and not self.surge_blocked
        ):
            self.surge_active = True
            self.surge_calm = 0
            self.emit(t_s, first, LOAD_SURGE, float(breach), True)


class BlameLedger:
    """Mirror of obs::attribute::BlameLedger: submit/kill/complete marks
    into exact per-chain blame decompositions."""

    def __init__(self):
        self.open = {}  # id -> [enq instants, kill (instant, was_timeout)]
        self.done = []

    def attempt_start(self, rid, t_s):
        self.open.setdefault(rid, ([], []))[0].append(t_s)

    def attempt_killed(self, rid, t_s, was_timeout):
        self.open.setdefault(rid, ([], []))[1].append((t_s, was_timeout))

    def complete(self, rid, start_s, done_s, exec_s, tx_s):
        enq, kill = self.open.pop(rid, ([], []))
        queue_wasted_s = 0.0
        retry_wait_s = 0.0
        timeout_kills = 0
        crash_kills = 0
        for i, (k, was_timeout) in enumerate(kill):
            queue_wasted_s += k - enq[i]
            retry_wait_s += enq[i + 1] - k
            if was_timeout:
                timeout_kills += 1
            else:
                crash_kills += 1
        last_enq = enq[-1] if enq else start_s
        queue_s = start_s - last_enq
        batch_wait_s = (done_s - start_s) - exec_s
        total_s = (
            queue_wasted_s + retry_wait_s + queue_s + batch_wait_s + exec_s + tx_s
        )
        self.done.append(
            {
                "id": rid,
                "attempts": len(enq),
                "timeout_kills": timeout_kills,
                "crash_kills": crash_kills,
                "enq_s": enq,
                "kill_s": [t for t, _ in kill],
                "start_s": start_s,
                "done_s": done_s,
                "queue_wasted_s": queue_wasted_s,
                "retry_wait_s": retry_wait_s,
                "queue_s": queue_s,
                "batch_wait_s": batch_wait_s,
                "exec_s": exec_s,
                "tx_s": tx_s,
                "total_s": total_s,
            }
        )


def _bits(x):
    return struct.pack("<d", x)


def verify_blame(chains):
    """Mirror of obs::verify::verify_blame: recompute every segment from
    the raw chain marks and demand bit-equality on the refold."""
    for c in chains:
        rid = c["id"]
        assert c["attempts"] >= 1 and len(c["enq_s"]) == c["attempts"], rid
        assert len(c["kill_s"]) + 1 == len(c["enq_s"]), rid
        assert c["timeout_kills"] + c["crash_kills"] == len(c["kill_s"]), rid
        for i, k in enumerate(c["kill_s"]):
            assert c["enq_s"][i] <= k <= c["enq_s"][i + 1], rid
        assert c["enq_s"][-1] <= c["start_s"] <= c["done_s"], rid
        qw = 0.0
        rw = 0.0
        for i, k in enumerate(c["kill_s"]):
            qw += k - c["enq_s"][i]
            rw += c["enq_s"][i + 1] - k
        q = c["start_s"] - c["enq_s"][-1]
        bw = (c["done_s"] - c["start_s"]) - c["exec_s"]
        total = qw + rw + q + bw + c["exec_s"] + c["tx_s"]
        for got, want in (
            (c["queue_wasted_s"], qw),
            (c["retry_wait_s"], rw),
            (c["queue_s"], q),
            (c["batch_wait_s"], bw),
            (c["total_s"], total),
        ):
            assert _bits(got) == _bits(want), f"chain {rid}: blame refold diverged"


def score_alerts(alerts, expect, onset_s):
    """Mirror of obs::attribute::score_alerts: expect is (kind, lane) or
    None for a fault-free run (every raise false)."""
    detected = False
    latency = float("nan")
    correct = False
    false_alerts = 0
    for a in alerts:
        if not a["raised"]:
            continue
        if expect is not None and a["kind"] == expect[0] and a["t_s"] >= onset_s:
            if not detected:
                detected = True
                latency = a["t_s"] - onset_s
                correct = a["lane"] == expect[1]
        else:
            false_alerts += 1
    return {
        "detected": detected,
        "detection_latency_s": latency,
        "correct_lane": correct,
        "false_alerts": false_alerts,
    }


def compress_arrivals(pool, onset_s, rate):
    """Mirror of experiments::detect::compress_arrivals: post-onset
    inter-arrival gaps shrink x`rate`, same request bodies."""
    out = []
    for r in pool:
        a = r.arrival_s
        if a > onset_s:
            a = onset_s + (r.arrival_s - onset_s) / rate
        out.append(RequestTruth(r.n, r.m_real, a, r.t_edge, r.t_cloud, r.t_tx, r.rtt))
    return out


def run_detect_eval(requests, seed=SEED):
    """Run the five-scenario evaluation (mirror of
    experiments::detect::run, serial cell order)."""
    topo = topo_hetero()
    tiers = [d["tier"] for d in topo["devices"]]
    crash = outage_fault_spec(topo, requests, OUTAGE_OFFERED_RPS)
    onset_s = crash["start_s"]
    slow = {
        "lane": crash["lane"],
        "mode": "slow",
        "factor": SLOW_FACTOR,
        "start_s": crash["start_s"],
        "recover_s": crash["recover_s"],
    }
    link_lane = next(
        i for i, d in enumerate(topo["devices"]) if d["tier"] == CLOUD
    )
    link = {
        "lane": link_lane,
        "mode": "link",
        "factor": LINK_FACTOR,
        "start_s": crash["start_s"],
        "recover_s": crash["recover_s"],
    }
    pool = synth_workload(
        cell_seed(seed, 0) ^ OUTAGE_SEED_TAG, requests, OUTAGE_OFFERED_RPS
    )
    surge_pool = compress_arrivals(pool, onset_s, SURGE_RATE)
    faults = [None, crash, slow, link, None]
    expects = {
        "twin": (None, False, 0.0),
        "crash": ((DEVICE_CRASH, crash["lane"]), True, onset_s),
        "slow": ((DEVICE_SLOWDOWN, slow["lane"]), True, onset_s),
        "link": ((LINK_DEGRADATION, link["lane"]), True, onset_s),
        "surge": ((LOAD_SURGE, 0), False, onset_s),
    }
    scenarios = []
    for cell, name in enumerate(SCENARIOS):
        reqs = surge_pool if name == "surge" else pool
        det = Detector(tiers, dict(DETECT_CFG))
        blame = BlameLedger()
        run = OutageRun(
            reqs,
            topo,
            True,
            faults[cell],
            RETRY_POLICY,
            telemetry=dict(TELEMETRY_CFG),
            detector=det,
            blame=blame,
        )
        result = run.run()
        verify_blame(blame.done)
        expect, lane_attributable, onset = expects[name]
        scenarios.append(
            {
                "name": name,
                "fault": faults[cell],
                "expect": expect,
                "lane_attributable": lane_attributable,
                "onset_s": onset,
                "result": result,
                "alerts": det.log,
                "raised": det.raised,
                "cleared": det.cleared,
                "score": score_alerts(det.log, expect, onset),
                "blame": blame.done,
            }
        )
    twin = scenarios[0]
    if twin["raised"] != 0:
        raise RuntimeError(
            f"detect eval: fault-free twin raised {twin['raised']} alert(s) — "
            "the detector is mistuned for this operating point"
        )
    return topo, scenarios


def detected_count(scenarios):
    return sum(
        1 for s in scenarios if s["expect"] is not None and s["score"]["detected"]
    )


def false_alert_count(scenarios):
    return sum(s["score"]["false_alerts"] for s in scenarios)


def max_detection_latency_s(scenarios):
    """Fold NAN f64::max over detected latencies (NaN when none)."""
    lat = [
        s["score"]["detection_latency_s"]
        for s in scenarios
        if s["score"]["detected"]
    ]
    return max(lat) if lat else float("nan")


def attribution_accuracy(scenarios):
    faulted = [s for s in scenarios if s["expect"] is not None]
    if not faulted:
        return float("nan")
    good = sum(
        1
        for s in faulted
        if s["score"]["detected"]
        and (not s["lane_attributable"] or s["score"]["correct_lane"])
    )
    return good / len(faulted)


def alert_to_json(a):
    return {
        "t_s": a["t_s"],
        "lane": float(a["lane"]),
        "kind": a["kind"],
        "raised": a["raised"],
        "score": a["score"],
    }


def chain_to_json(c):
    return {
        "id": float(c["id"]),
        "attempts": float(c["attempts"]),
        "timeout_kills": float(c["timeout_kills"]),
        "crash_kills": float(c["crash_kills"]),
        "queue_wasted_s": c["queue_wasted_s"],
        "retry_wait_s": c["retry_wait_s"],
        "queue_s": c["queue_s"],
        "batch_wait_s": c["batch_wait_s"],
        "exec_s": c["exec_s"],
        "tx_s": c["tx_s"],
        "total_s": c["total_s"],
    }


def blame_to_json(chains):
    """Per-segment sums accumulated in completion order (the rust fold
    order), plus the retried chains in full."""
    sums = [0.0] * 7
    attempts = 0
    timeout_kills = 0
    crash_kills = 0
    retried = []
    for c in chains:
        attempts += c["attempts"]
        timeout_kills += c["timeout_kills"]
        crash_kills += c["crash_kills"]
        for slot, key in enumerate(
            (
                "queue_wasted_s",
                "retry_wait_s",
                "queue_s",
                "batch_wait_s",
                "exec_s",
                "tx_s",
                "total_s",
            )
        ):
            sums[slot] += c[key]
        if c["attempts"] > 1:
            retried.append(chain_to_json(c))
    return {
        "chains": float(len(chains)),
        "attempts": float(attempts),
        "timeout_kills": float(timeout_kills),
        "crash_kills": float(crash_kills),
        "queue_wasted_s": sums[0],
        "retry_wait_s": sums[1],
        "queue_s": sums[2],
        "batch_wait_s": sums[3],
        "exec_s": sums[4],
        "tx_s": sums[5],
        "total_s": sums[6],
        "retried": retried,
    }


def score_to_json(s):
    return {
        "detected": s["detected"],
        # NaN renders as null (write_num) — matches the rust Json::Null.
        "detection_latency_s": s["detection_latency_s"],
        "correct_lane": s["correct_lane"],
        "false_alerts": float(s["false_alerts"]),
    }


def detect_to_json(topo, scenarios, requests, seed=SEED):
    scen = {}
    for s in scenarios:
        scen[s["name"]] = {
            # Python None has no renderer; NaN renders null like rust's
            # Json::Null for the absent fault/expect.
            "fault": (
                fault_to_json(s["fault"])
                if s["fault"] is not None
                else float("nan")
            ),
            "expect": (
                {"kind": s["expect"][0], "lane": float(s["expect"][1])}
                if s["expect"] is not None
                else float("nan")
            ),
            "lane_attributable": s["lane_attributable"],
            "onset_s": s["onset_s"],
            "result": s["result"],
            "alerts": [alert_to_json(a) for a in s["alerts"]],
            "score": score_to_json(s["score"]),
            "blame": blame_to_json(s["blame"]),
        }
    return {
        "seed": float(seed),
        "requests_per_point": float(requests),
        "offered_rps": OUTAGE_OFFERED_RPS,
        "topology": topo_to_json(topo),
        "detect": {
            "warmup": float(DETECT_CFG["warmup"]),
            "cusum_k": DETECT_CFG["cusum_k"],
            "cusum_h": DETECT_CFG["cusum_h"],
            "sigma_floor": DETECT_CFG["sigma_floor"],
            "clear_after": float(DETECT_CFG["clear_after"]),
            "gauge_warmup": float(DETECT_CFG["gauge_warmup"]),
            "gauge_lambda": DETECT_CFG["gauge_lambda"],
            "gauge_l": DETECT_CFG["gauge_l"],
            "surge_lanes": float(DETECT_CFG["surge_lanes"]),
            "surge_clear": float(DETECT_CFG["surge_clear"]),
        },
        "retry": {
            "timeout_mult": RETRY_POLICY["timeout_mult"],
            "min_timeout_s": RETRY_POLICY["min_timeout_s"],
            "backoff_base_s": RETRY_POLICY["backoff_base_s"],
            "backoff_mult": RETRY_POLICY["backoff_mult"],
            "max_retries": float(RETRY_POLICY["max_retries"]),
        },
        "telemetry_interval_s": TELEMETRY_CFG["interval_s"],
        "slow_factor": SLOW_FACTOR,
        "link_factor": LINK_FACTOR,
        "surge_rate": SURGE_RATE,
        "goodput_window_s": GOODPUT_WINDOW_S,
        "scenarios": scen,
        "headline_detected": float(detected_count(scenarios)),
        "headline_false_alerts": float(false_alert_count(scenarios)),
        "headline_max_detection_latency_s": max_detection_latency_s(scenarios),
        "headline_attribution_accuracy": attribution_accuracy(scenarios),
    }


def summarize(scenarios):
    hdr = (
        f"{'scenario':<8} {'expected':>16} {'raised':>7} {'clears':>7} "
        f"{'latency_s':>9} {'lane':>5} {'false':>6} {'chains':>7}"
    )
    print(hdr)
    print("-" * len(hdr))
    for s in scenarios:
        expected = s["expect"][0] if s["expect"] is not None else "-"
        sc = s["score"]
        latency = f"{sc['detection_latency_s']:.3f}" if sc["detected"] else "-"
        if sc["detected"] and s["lane_attributable"]:
            lane = "ok" if sc["correct_lane"] else "WRONG"
        else:
            lane = "-"
        print(
            f"{s['name']:<8} {expected:>16} {s['raised']:>7} {s['cleared']:>7} "
            f"{latency:>9} {lane:>5} {sc['false_alerts']:>6} "
            f"{len(s['blame']):>7}"
        )
    faulted = sum(1 for s in scenarios if s["expect"] is not None)
    print(
        f"\nheadline: {detected_count(scenarios)}/{faulted} faults detected "
        f"(worst latency {max_detection_latency_s(scenarios):.3f}s), "
        f"attribution accuracy {attribution_accuracy(scenarios) * 100:.0f}%, "
        f"{false_alert_count(scenarios)} false alert(s), twin quiescent"
    )


def run_off_check(requests, seed=SEED):
    """Observation-only proof: the crash replay's scheduling outcome is
    identical with the detector + blame ledger attached and detached."""
    topo = topo_hetero()
    tiers = [d["tier"] for d in topo["devices"]]
    fault = outage_fault_spec(topo, requests, OUTAGE_OFFERED_RPS)
    pool = synth_workload(
        cell_seed(seed, 0) ^ OUTAGE_SEED_TAG, requests, OUTAGE_OFFERED_RPS
    )
    attached = OutageRun(
        pool,
        topo,
        True,
        fault,
        RETRY_POLICY,
        telemetry=dict(TELEMETRY_CFG),
        detector=Detector(tiers, dict(DETECT_CFG)),
        blame=BlameLedger(),
    ).run()
    detached = OutageRun(
        pool, topo, True, fault, RETRY_POLICY, telemetry=dict(TELEMETRY_CFG)
    ).run()
    a = to_json_value(attached, 2, 0)
    d = to_json_value(detached, 2, 0)
    if a != d:
        raise RuntimeError(
            "detection is not observation-only: attached/detached outage "
            "replays diverged"
        )
    print(
        f"off-check ok: {requests} requests, detector-attached replay "
        "byte-identical with detection off"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--requests",
        type=int,
        default=OUTAGE_REQUESTS,
        help="requests per scenario (mirrors cnmt --detect-requests)",
    )
    ap.add_argument(
        "--off-check",
        action="store_true",
        help="skip the eval; prove the detector is observation-only "
        "(attached vs detached crash replays byte-identical)",
    )
    args = ap.parse_args()

    if args.off_check:
        run_off_check(args.requests)
        return
    topo, scenarios = run_detect_eval(args.requests)
    root = detect_to_json(topo, scenarios, args.requests)
    write_json(args.out or "reports/detect_eval.json", root)
    summarize(scenarios)


if __name__ == "__main__":
    main()
