#!/usr/bin/env python3
"""Standalone mirror of `cnmt experiment fleet --closed-loop --telemetry`
(reports/telemetry_drift.json).

The drift-telemetry report is the closed-loop fleet sweep of
`fleet_sweep_mirror.py` pinned to the contended K=32 point with the
control-loop sampler switched on: every per-policy block gains a
`phases` latency decomposition (queue_wait / batch_wait / exec / tx
histograms that partition each result's latency exactly) and a
`telemetry` block of fixed-cadence gauge time-series (live queue depth,
backlog expected-wait, busy workers per device, plus the installed RLS
plane coefficients, hedge margin and windowed wasted-work fraction on
the adaptive/controlled configurations). The root gains the sampler
parameters and a compressed `drift_story`: the throttled device's
backlog rising under the tier-baseline selector, the refit plane
stepping toward the drifted truth, the hedge margin settling with its
windowed waste near the budget.

Telemetry only observes — the sampler reads the pre-action dispatcher
state and never writes back — so every aggregate in this report is
bit-identical to the untelemetered `fleet_closed_loop.json` run at the
same client count. Keep this file in lockstep with
rust/src/obs/telemetry.rs and rust/src/experiments/fleet.rs (the
`drift telemetry` section): when both toolchains are available, `cnmt
experiment fleet --closed-loop --telemetry --out reports` and this
script must agree bit-for-bit.

Usage:
    python3 python/tools/telemetry_mirror.py [--out reports/telemetry_drift.json]
    python3 python/tools/telemetry_mirror.py --requests 4000 --clients 16
"""

import argparse
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from fleet_sweep_mirror import (  # noqa: E402
    REQUESTS_PER_POINT,
    SEED,
    check_pair_anchor,
    closed_sweep_to_json,
    run_closed_sweep,
    write_json,
)

# experiments::fleet drift-telemetry constants.
TELEMETRY_INTERVAL_S = 2.0
TELEMETRY_CAPACITY = 64
TELEMETRY_CLIENTS = 32


def telemetry_cfg():
    """Mirror of the TelemetryCfg carried by fleet::telemetry_config."""
    return {"interval_s": TELEMETRY_INTERVAL_S, "capacity": TELEMETRY_CAPACITY}


def _fmax(a, b):
    """Mirror of f64::max (returns the other operand on NaN)."""
    if math.isnan(a):
        return b
    if math.isnan(b):
        return a
    return a if a > b else b


def series_story(xs):
    """Mirror of experiments::fleet::series_story: (first, peak, last)
    of one gauge series, NaNs when empty."""
    first = xs[0] if xs else float("nan")
    peak = float("nan")
    for x in xs:
        peak = _fmax(peak, x)
    last = xs[-1] if xs else float("nan")
    return first, peak, last


def telemetry_story(drift, cells):
    """Mirror of experiments::fleet::telemetry_story: the compressed
    drift-story diagnostics read off the last cell's gauge series."""
    lane = drift["lane"]
    o = {"drift_lane": float(lane)}
    if not cells:
        return o
    policies = cells[-1]["policies"]
    # Tier-baseline selector: the stale plane keeps under-pricing the
    # throttled device, so its sampled backlog climbs.
    tel = policies["fleet+select"].get("telemetry")
    if tel is not None:
        first, peak, last = series_story(tel["devices"][lane]["expected_wait_s"])
        o["baseline_backlog_first_s"] = first
        o["baseline_backlog_peak_s"] = peak
        o["baseline_backlog_last_s"] = last
    # Per-device refit: the throttled replica's installed plane steps
    # toward the drifted ground truth.
    tel = policies["fleet+select+refit"].get("telemetry")
    if tel is not None and "plane_an" in tel["devices"][lane]:
        first, _, last = series_story(tel["devices"][lane]["plane_an"])
        o["refit_plane_an_first"] = first
        o["refit_plane_an_last"] = last
        o["refit_plane_an_ratio"] = last / first
    # Budget-controlled hedging: margin settles, windowed waste pins
    # near the configured budget.
    tel = policies["fleet+hedge+refit"].get("telemetry")
    if tel is not None:
        if "hedge_margin_s" in tel:
            _, _, last = series_story(tel["hedge_margin_s"])
            o["hedge_margin_last_s"] = last
        if "wasted_frac" in tel:
            _, _, last = series_story(tel["wasted_frac"])
            o["wasted_frac_last"] = last
    return o


def telemetry_to_json(topo, drift, cells, requests_per_point, think_s, seed=SEED):
    """Mirror of experiments::fleet::telemetry_to_json: the closed-loop
    report plus the sampler parameters and the drift story."""
    root = closed_sweep_to_json(topo, drift, cells, requests_per_point, think_s, seed)
    root["telemetry_interval_s"] = TELEMETRY_INTERVAL_S
    root["telemetry_capacity"] = float(TELEMETRY_CAPACITY)
    root["drift_story"] = telemetry_story(drift, cells)
    return root


def summarize(drift, cells, story, waste_budget):
    for c in cells:
        for label, r in c["policies"].items():
            tel = r.get("telemetry")
            if tel is None:
                continue
            print(
                f"K={c['clients']} {label:<19} samples={int(tel['samples']):>3} "
                f"truncated={tel['truncated']} "
                f"phase mean q/b/e/t ms="
                + "/".join(
                    f"{r['phases'][k]['mean_s'] * 1e3:.2f}"
                    for k in ("queue_wait", "batch_wait", "exec", "tx")
                )
            )
    if "baseline_backlog_peak_s" in story:
        print(
            f"\ntelemetry: throttled device (lane {drift['lane']}) backlog "
            f"{story['baseline_backlog_first_s'] * 1e3:.1f} ms -> "
            f"{story['baseline_backlog_peak_s'] * 1e3:.1f} ms peak under the "
            "tier-baseline selector"
        )
    if "refit_plane_an_ratio" in story:
        print(
            f"telemetry: refit stepped the throttled plane a_N "
            f"{story['refit_plane_an_ratio']:.2f}x toward the "
            f"{drift['factor']:.1f}x drifted truth"
        )
    if "hedge_margin_last_s" in story:
        print(
            f"telemetry: hedge margin settled at "
            f"{story['hedge_margin_last_s'] * 1e3:.2f} ms with windowed waste "
            f"{story['wasted_frac_last'] * 100:.1f}% against the "
            f"{waste_budget * 100:.0f}% budget"
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--requests",
        type=int,
        default=REQUESTS_PER_POINT,
        help="request bodies per cell (mirrors cnmt --fleet-requests)",
    )
    ap.add_argument(
        "--clients",
        default=None,
        help=f"comma-separated client counts (default {TELEMETRY_CLIENTS})",
    )
    ap.add_argument(
        "--think-ms",
        type=float,
        default=0.0,
        help="per-client think time in ms (mirrors cnmt --think-ms)",
    )
    ap.add_argument(
        "--anchor-requests",
        type=int,
        default=4000,
        help="request count of the always-on 1x1 pair-equivalence check (0 skips)",
    )
    args = ap.parse_args()

    if args.anchor_requests > 0:
        check_pair_anchor(args.anchor_requests)

    clients = (
        [int(s) for s in args.clients.split(",")]
        if args.clients
        else [TELEMETRY_CLIENTS]
    )
    think_s = args.think_ms / 1e3
    topo, drift, cells = run_closed_sweep(
        clients, args.requests, think_s, telemetry=telemetry_cfg()
    )
    root = telemetry_to_json(topo, drift, cells, args.requests, think_s)
    write_json(args.out or "reports/telemetry_drift.json", root)
    summarize(drift, cells, root["drift_story"], root["waste_budget"])


if __name__ == "__main__":
    main()
