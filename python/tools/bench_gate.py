#!/usr/bin/env python3
"""Perf gate over a rust-native `cnmt bench sched --json` report.

Floors are deliberately generous (a noisy shared CI runner must not
flake the build); the point is to catch order-of-magnitude regressions
in the zero-churn dispatcher and the parallel sweep runner:

  * single-thread event-loop throughput ≥ --min-events-per-sec;
  * dense dispatcher ≥ --min-speedup x the frozen pre-rewrite baseline
    (`scheduler::baseline`) on both the solo and hedged streams;
  * the fleet path (FleetSelector + N-lane surface) on the 1x1 shape
    runs at ≥ --min-fleet-ratio x the classic pair path's events/sec —
    the lane generalisation must stay within a few percent, not an
    order of magnitude;
  * the sharded sweep is bit-identical to the serial one and at least
    --min-sweep-speedup x faster at the bench's thread count;
  * the flight recorder costs almost nothing: the hedged event loop
    with a bounded decision-log ring attached runs at
    ≥ --min-recorder-ratio x the untraced loop's events/sec;
  * the failure machinery costs almost nothing when nothing fails: the
    fleet loop with deadline timers armed on every admitted request
    runs at ≥ --min-failover-ratio x the untimed fleet loop's
    events/sec. A report without the `failover` section fails the gate
    outright (the bench regressed out of measuring it);
  * self-diagnosis costs almost nothing: the hedged event loop with
    the online anomaly detector tapping every completion's execution
    residual runs at ≥ --min-detect-ratio x the untapped loop's
    events/sec. A report without the `detector` section fails the
    gate outright (the bench regressed out of measuring it);
  * the binary workload-trace codec (`cnmt::trace`) encodes and
    decodes at ≥ --min-trace-events records/sec — replaying a
    million-request trace must stay I/O-trivial next to the
    simulation itself. A report without the `trace` section fails the
    gate outright (the bench regressed out of measuring it);
  * service classes cost almost nothing: the scenario replay with the
    fair EDF front-end, class-aware hedge bar and batch-aware waits
    runs at ≥ --min-scenario-ratio x the class-blind FIFO replay's
    requests/sec. A report without the `scenario` section fails the
    gate outright (the bench regressed out of measuring it).

Usage: python3 bench_gate.py BENCH_sched.json [--min-events-per-sec N]
       [--min-speedup X] [--min-fleet-ratio X] [--min-sweep-speedup X]
       [--min-recorder-ratio X] [--min-failover-ratio X]
       [--min-detect-ratio X] [--min-trace-events N]
       [--min-scenario-ratio X]
"""

import argparse
import json
import sys


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report")
    ap.add_argument("--min-events-per-sec", type=float, default=100_000.0)
    ap.add_argument("--min-speedup", type=float, default=1.2)
    ap.add_argument("--min-fleet-ratio", type=float, default=0.8)
    ap.add_argument("--min-sweep-speedup", type=float, default=1.5)
    ap.add_argument("--min-recorder-ratio", type=float, default=0.9)
    ap.add_argument("--min-failover-ratio", type=float, default=0.9)
    ap.add_argument("--min-detect-ratio", type=float, default=0.9)
    ap.add_argument("--min-trace-events", type=float, default=200_000.0)
    ap.add_argument("--min-scenario-ratio", type=float, default=0.9)
    args = ap.parse_args()

    with open(args.report) as f:
        b = json.load(f)
    if b.get("python_proxy"):
        print("refusing to gate on a python-proxy report; regenerate with "
              "`cnmt bench sched --json`")
        sys.exit(1)

    eps_solo = b["event_loop_solo"]["events_per_sec"]
    eps_hedged = b["event_loop_hedged"]["events_per_sec"]
    sp_solo = b["speedup"]["event_loop_solo"]
    sp_hedged = b["speedup"]["event_loop_hedged"]
    fleet = b["fleet"]
    fleet_ratio = fleet["ratio_vs_pair_solo"]
    sweep = b["sweep"]
    recorder = b["recorder"]
    failover = b.get("failover")
    detector = b.get("detector")
    trace = b.get("trace")
    scenario = b.get("scenario")
    print(
        f"events/sec: solo {eps_solo:,.0f}, hedged {eps_hedged:,.0f} | "
        f"speedup vs frozen baseline: solo {sp_solo:.2f}x, hedged "
        f"{sp_hedged:.2f}x | fleet 1x1 path "
        f"{fleet['lane2']['events_per_sec']:,.0f} ev/s "
        f"({fleet_ratio:.2f}x pair), 4x2 "
        f"{fleet['lane6']['events_per_sec']:,.0f} ev/s | "
        f"sweep {sweep['serial_wall_s']:.2f}s → "
        f"{sweep['parallel_wall_s']:.2f}s at {sweep['threads']:.0f} threads "
        f"({sweep['speedup']:.2f}x, bit_identical={sweep['bit_identical']}) | "
        f"recorder {recorder['ratio']:.2f}x "
        f"(ring {recorder['capacity']:.0f})"
    )
    if failover is not None:
        print(
            f"failover-armed fleet loop: "
            f"{failover['armed']['events_per_sec']:,.0f} ev/s on "
            f"{failover['armed']['topology']} "
            f"({failover['ratio']:.2f}x the untimed loop)"
        )
    if detector is not None:
        print(
            f"detector-tapped hedged loop: "
            f"{detector['enabled']['events_per_sec']:,.0f} ev/s "
            f"({detector['ratio']:.2f}x the untapped loop)"
        )
    if trace is not None:
        print(
            f"trace codec: encode {trace['encode']['events_per_sec']:,.0f} ev/s, "
            f"decode {trace['decode']['events_per_sec']:,.0f} ev/s "
            f"({trace['bytes_per_record']:.2f} B/record)"
        )
    if scenario is not None:
        print(
            f"scenario replay: edf "
            f"{scenario['edf']['requests_per_sec']:,.0f} req/s vs fifo "
            f"{scenario['fifo']['requests_per_sec']:,.0f} req/s "
            f"({scenario['ratio']:.2f}x)"
        )

    failures = []
    if trace is None:
        failures.append(
            "report has no `trace` section (bench stopped measuring the "
            "workload-trace codec)"
        )
    else:
        for side in ("encode", "decode"):
            eps = trace[side]["events_per_sec"]
            if eps < args.min_trace_events:
                failures.append(
                    f"trace {side} {eps:,.0f} records/sec < floor "
                    f"{args.min_trace_events:,.0f}"
                )
    if eps_solo < args.min_events_per_sec:
        failures.append(
            f"solo events/sec {eps_solo:,.0f} < floor {args.min_events_per_sec:,.0f}"
        )
    if sp_solo < args.min_speedup or sp_hedged < args.min_speedup:
        failures.append(
            f"speedup vs baseline ({sp_solo:.2f}x / {sp_hedged:.2f}x) below "
            f"floor {args.min_speedup:.2f}x"
        )
    if fleet_ratio < args.min_fleet_ratio:
        failures.append(
            f"fleet 1x1 path at {fleet_ratio:.2f}x the pair path, below "
            f"floor {args.min_fleet_ratio:.2f}x (lane generalisation regressed)"
        )
    if sweep["bit_identical"] is not True:
        failures.append("parallel sweep not bit-identical to serial")
    if recorder["ratio"] < args.min_recorder_ratio:
        failures.append(
            f"flight recorder drags the hedged loop to {recorder['ratio']:.2f}x, "
            f"below floor {args.min_recorder_ratio:.2f}x (decision log is no "
            "longer near-free)"
        )
    if failover is None:
        failures.append(
            "report has no `failover` section (bench stopped measuring the "
            "armed-timer overhead)"
        )
    elif failover["ratio"] < args.min_failover_ratio:
        failures.append(
            f"deadline timers drag the fleet loop to {failover['ratio']:.2f}x, "
            f"below floor {args.min_failover_ratio:.2f}x (failover machinery "
            "is no longer pay-for-use)"
        )
    if detector is None:
        failures.append(
            "report has no `detector` section (bench stopped measuring the "
            "anomaly-detector overhead)"
        )
    elif detector["ratio"] < args.min_detect_ratio:
        failures.append(
            f"anomaly detector drags the hedged loop to {detector['ratio']:.2f}x, "
            f"below floor {args.min_detect_ratio:.2f}x (self-diagnosis is no "
            "longer near-free)"
        )
    if scenario is None:
        failures.append(
            "report has no `scenario` section (bench stopped measuring the "
            "service-class overhead)"
        )
    elif scenario["ratio"] < args.min_scenario_ratio:
        failures.append(
            f"service classes drag the scenario replay to "
            f"{scenario['ratio']:.2f}x the class-blind loop, below floor "
            f"{args.min_scenario_ratio:.2f}x (EDF front-end is no longer "
            "pay-for-use)"
        )
    # The wall-clock floor is a function of available parallelism: a
    # 1-core runner degenerates to the serial path (speedup ~1.0) with
    # nothing regressed, so only gate it when the bench actually had
    # cores to spread over.
    threads = sweep["threads"]
    if threads >= 4:
        sweep_floor = args.min_sweep_speedup
    elif threads >= 2:
        sweep_floor = 1.1
    else:
        sweep_floor = None
        print("1 thread available: sweep-speedup floor skipped")
    if sweep_floor is not None and sweep["speedup"] < sweep_floor:
        failures.append(
            f"sweep speedup {sweep['speedup']:.2f}x below floor "
            f"{sweep_floor:.2f}x at {threads:.0f} threads"
        )
    if failures:
        for f_ in failures:
            print(f"GATE FAIL: {f_}")
        sys.exit(1)
    print("GATE PASS")


if __name__ == "__main__":
    main()
