#!/usr/bin/env python3
"""Standalone mirror of the binary workload-trace codec (rust/src/trace).

Why this exists: the compressed million-request scenario trace checked
in under `reports/` must be regenerable in environments that have no
rust toolchain, and the format needs a second, independent
implementation to validate against. This script re-implements, byte for
byte, exactly what the rust side does:

  * `util::rng::Rng`      — xoshiro256** + splitmix64 seeding, the
                            exponential / Box-Muller draws (cached
                            spare normal), shared with the sweep
                            mirrors;
  * `trace::SynthTrace`   — the µs-quantized synthetic scenario
                            (Poisson arrivals, correlated n→m lengths,
                            optional execution noise) in the same draw
                            order;
  * `trace::TraceWriter`  — the 96-byte versioned header (magic,
                            flags, ten f64 characterization fields,
                            CRC32), LEB128 varint records delta-encoded
                            in microseconds, 4096-record blocks each
                            sealed with a zlib CRC32, and the
                            record-count end marker;
  * `trace::TraceReader`  — the validating decoder (used by `info` and
                            by `gen`'s self-check).

`python3 trace_mirror.py gen --out t.ctr` and `cnmt trace record --out
t.ctr` (same seed/requests/load/noise) must produce identical bytes —
CI diffs them with `cmp`. A `.gz` destination is compressed
deterministically (mtime=0, level 9); CI compares the *decompressed*
bytes, so the gzip container never participates in the contract.

Usage:
    python3 python/tools/trace_mirror.py gen --out reports/trace_1m.ctr.gz \
        [--requests 1000000] [--load 96] [--seed 20220315] [--exec-noise 0]
    python3 python/tools/trace_mirror.py info <file[.gz]>
"""

import argparse
import gzip
import math
import struct
import sys
import zlib

MASK = (1 << 64) - 1

# ------------------------------------------------------------------ rng (util::rng)


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded via splitmix64 (mirror of util::rng::Rng)."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s
        self.spare_normal = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def exponential(self, lam):
        while True:
            u = self.f64()
            if u > 1e-300:
                break
        return -math.log(u) / lam

    def normal(self):
        if self.spare_normal is not None:
            z, self.spare_normal = self.spare_normal, None
            return z
        while True:
            u1 = self.f64()
            if u1 > 1e-300:
                break
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        a = 2.0 * math.pi * u2
        self.spare_normal = r * math.sin(a)
        return r * math.cos(a)

    def normal_ms(self, mean, std):
        return mean + std * self.normal()


# ------------------------------------------------------------------ format constants

TRACE_MAGIC = b"CNMTRACE"
TRACE_VERSION = 1
FLAG_TIMES_EXPLICIT = 1
HEADER_LEN = 96
BLOCK_RECORDS = 4096

# Scenario constants (experiments::load / trace::SynthTrace).
EDGE_PLANE = (1.2e-3, 3.0e-3, 6.0e-3)
CLOUD_PLANE = (0.22e-3, 0.55e-3, 26.0e-3)
N2M_GAMMA = 0.95
N2M_DELTA = 0.8
RTT_S = 0.042
MEAN_N = 17.0
SYNTH_M_NOISE_STD = 2.0
SYNTH_N_MAX = 62


def texe_estimate(plane, n, m):
    """Mirror of predictor::TexeModel::estimate (max with 0)."""
    an, am, b = plane
    return max(an * n + am * m + b, 0.0)


def s_to_us(s):
    """Mirror of trace::s_to_us: (s * 1e6 + 0.5).floor() as u64."""
    return int(math.floor(s * 1e6 + 0.5))


def us_to_s(us):
    return us * 1e-6


def rust_round(x):
    """f64::round — half away from zero (python round() is banker's).

    For the positive magnitudes this scenario produces, `x - floor(x)`
    is an exact float operation, so the half-way comparison is exact.
    """
    f = math.floor(x)
    r = x - f
    if r > 0.5 or (r == 0.5 and x > 0.0):
        return f + 1
    if r == 0.5:  # negative half-way: away from zero is downward
        return f
    return f if r < 0.5 else f + 1


# ------------------------------------------------------------------ synthetic scenario


def synth_records(seed, requests, offered_rps, exec_noise_std):
    """Yield (delta_us, n, m, e_us, c_us, tx_us) in trace::SynthTrace's
    exact draw order, every duration already on the µs grid."""
    rng = Rng(seed)
    rtt_us = s_to_us(RTT_S)
    last_us = 0
    cum_us = 0
    for _ in range(requests):
        dt = rng.exponential(offered_rps)
        n = 1 + min(int(rng.exponential(1.0 / MEAN_N)), SYNTH_N_MAX - 1)
        m_mean = N2M_GAMMA * n + N2M_DELTA
        m = int(min(max(rust_round(m_mean + rng.normal_ms(0.0, SYNTH_M_NOISE_STD)), 1.0),
                    float(SYNTH_N_MAX)))
        if exec_noise_std > 0.0:
            noise_e = max(1.0 + rng.normal_ms(0.0, exec_noise_std), 0.2)
            noise_c = max(1.0 + rng.normal_ms(0.0, exec_noise_std), 0.2)
        else:
            noise_e = noise_c = 1.0
        cum_us += s_to_us(dt)
        e_us = s_to_us(texe_estimate(EDGE_PLANE, n, m) * noise_e)
        c_us = s_to_us(texe_estimate(CLOUD_PLANE, n, m) * noise_c)
        yield cum_us - last_us, n, m, e_us, c_us, rtt_us
        last_us = cum_us


# ------------------------------------------------------------------ encoder


def put_varint(buf, v):
    while True:
        b = v & 0x7F
        v >>= 7
        if v == 0:
            buf.append(b)
            return
        buf.append(b | 0x80)


def encode_header(flags, mean_m):
    h = bytearray()
    h += TRACE_MAGIC
    h += struct.pack("<H", TRACE_VERSION)
    h += struct.pack("<H", flags)
    for f in (*EDGE_PLANE, *CLOUD_PLANE, N2M_GAMMA, N2M_DELTA, mean_m, RTT_S):
        h += struct.pack("<d", f)
    h += struct.pack("<I", zlib.crc32(bytes(h)))
    assert len(h) == HEADER_LEN
    return bytes(h)


def encode_trace(seed, requests, offered_rps, exec_noise_std):
    """The full .ctr byte stream for the spec (mirror of
    trace::record_synth: mean_m prepass, then a second streaming
    generation pass)."""
    explicit = exec_noise_std > 0.0
    sum_m = 0
    for _, _, m, _, _, _ in synth_records(seed, requests, offered_rps, exec_noise_std):
        sum_m += m
    mean_m = sum_m / max(requests, 1)
    out = bytearray(encode_header(FLAG_TIMES_EXPLICIT if explicit else 0, mean_m))
    block = bytearray()
    n_in_block = 0

    def flush_block():
        nonlocal block, n_in_block
        if n_in_block == 0:
            return
        out.extend(struct.pack("<II", n_in_block, len(block)))
        out.extend(block)
        out.extend(struct.pack("<I", zlib.crc32(bytes(block))))
        block = bytearray()
        n_in_block = 0

    for delta, n, m, e_us, c_us, tx_us in synth_records(
        seed, requests, offered_rps, exec_noise_std
    ):
        put_varint(block, delta)
        put_varint(block, n)
        put_varint(block, m)
        if explicit:
            put_varint(block, e_us)
            put_varint(block, c_us)
            put_varint(block, tx_us)
        n_in_block += 1
        if n_in_block >= BLOCK_RECORDS:
            flush_block()
    flush_block()
    payload = struct.pack("<Q", requests)
    out.extend(struct.pack("<II", 0, len(payload)))
    out.extend(payload)
    out.extend(struct.pack("<I", zlib.crc32(payload)))
    return bytes(out)


# ------------------------------------------------------------------ decoder


class TraceError(Exception):
    pass


def get_varint(buf, pos):
    v = 0
    shift = 0
    while True:
        if pos >= len(buf):
            raise TraceError("varint runs past its block payload")
        b = buf[pos]
        pos += 1
        if shift > 63:
            raise TraceError("varint overflows u64")
        v |= (b & 0x7F) << shift
        if b & 0x80 == 0:
            return v, pos
        shift += 7


def decode_trace(data):
    """Validate + decode a .ctr byte stream; returns (header dict,
    iterator-exhausted record list of (arrival_us, n, m, e_us, c_us,
    tx_us))."""
    if len(data) < HEADER_LEN:
        raise TraceError("truncated trace: incomplete header")
    hb = data[:HEADER_LEN]
    if hb[:8] != TRACE_MAGIC:
        raise TraceError("not a cnmt trace (bad magic)")
    (stored,) = struct.unpack("<I", hb[92:96])
    if zlib.crc32(hb[:92]) != stored:
        raise TraceError("header crc mismatch (corrupted trace)")
    (version,) = struct.unpack("<H", hb[8:10])
    if version != TRACE_VERSION:
        raise TraceError(f"unsupported trace version {version}")
    (flags,) = struct.unpack("<H", hb[10:12])
    fields = struct.unpack("<10d", hb[12:92])
    header = {
        "version": version,
        "flags": flags,
        "edge_plane": fields[0:3],
        "cloud_plane": fields[3:6],
        "n2m_gamma": fields[6],
        "n2m_delta": fields[7],
        "mean_m": fields[8],
        "rtt_s": fields[9],
    }
    explicit = flags & FLAG_TIMES_EXPLICIT != 0
    rtt_us = s_to_us(header["rtt_s"])
    records = []
    at = HEADER_LEN
    cum_us = 0
    while True:
        if len(data) < at + 8:
            raise TraceError("truncated trace: incomplete block length prefix")
        n, ln = struct.unpack("<II", data[at:at + 8])
        at += 8
        if len(data) < at + ln + 4:
            raise TraceError("truncated trace: incomplete block payload")
        payload = data[at:at + ln]
        at += ln
        (stored,) = struct.unpack("<I", data[at:at + 4])
        at += 4
        if zlib.crc32(payload) != stored:
            raise TraceError("block crc mismatch (corrupted trace)")
        if n == 0:
            if ln != 8:
                raise TraceError("malformed end marker")
            (total,) = struct.unpack("<Q", payload)
            if total != len(records):
                raise TraceError(
                    f"record count mismatch: end marker says {total}, "
                    f"stream held {len(records)}"
                )
            return header, records
        pos = 0
        for _ in range(n):
            delta, pos = get_varint(payload, pos)
            rn, pos = get_varint(payload, pos)
            rm, pos = get_varint(payload, pos)
            cum_us += delta
            if explicit:
                e_us, pos = get_varint(payload, pos)
                c_us, pos = get_varint(payload, pos)
                tx_us, pos = get_varint(payload, pos)
            else:
                e_us = s_to_us(texe_estimate(header["edge_plane"], rn, rm))
                c_us = s_to_us(texe_estimate(header["cloud_plane"], rn, rm))
                tx_us = rtt_us
            records.append((cum_us, rn, rm, e_us, c_us, tx_us))
        if pos != len(payload):
            raise TraceError("block payload has trailing bytes")


# ------------------------------------------------------------------ commands


def read_maybe_gz(path):
    if path.endswith(".gz"):
        with gzip.open(path, "rb") as f:
            return f.read()
    with open(path, "rb") as f:
        return f.read()


def cmd_gen(args):
    data = encode_trace(args.seed, args.requests, args.load, args.exec_noise)
    # Self-check: the bytes we are about to publish must decode cleanly
    # back to the generator's own stream.
    header, records = decode_trace(data)
    assert len(records) == args.requests
    check = list(
        synth_records(args.seed, args.requests, args.load, args.exec_noise)
    )
    cum = 0
    for i, ((delta, n, m, e, c, tx), (a_us, rn, rm, re_, rc, rtx)) in enumerate(
        zip(check, records)
    ):
        cum += delta
        if (cum, n, m, e, c, tx) != (a_us, rn, rm, re_, rc, rtx):
            raise SystemExit(f"self-check failed at record {i}")
    if args.out.endswith(".gz"):
        # filename='' suppresses the FNAME header field and mtime=0 the
        # timestamp, so the .gz bytes depend only on the trace content.
        with open(args.out, "wb") as raw:
            with gzip.GzipFile(
                filename="", fileobj=raw, mode="wb", compresslevel=9, mtime=0
            ) as gz:
                gz.write(data)
    else:
        with open(args.out, "wb") as f:
            f.write(data)
    mode = "explicit-times" if args.exec_noise > 0.0 else "derived"
    print(
        f"wrote {args.out}: {args.requests} records, {len(data)} bytes "
        f"uncompressed ({mode} mode, seed {args.seed}, {args.load} r/s)"
    )


def cmd_info(args):
    header, records = decode_trace(read_maybe_gz(args.file))
    n_rec = len(records)
    duration_s = us_to_s(records[-1][0]) if records else 0.0
    mean_n = sum(r[1] for r in records) / max(n_rec, 1)
    mean_m = sum(r[2] for r in records) / max(n_rec, 1)
    offered = n_rec / duration_s if duration_s > 0 else 0.0
    print(
        f"version {header['version']} "
        f"({'explicit-times' if header['flags'] & FLAG_TIMES_EXPLICIT else 'derived'} "
        f"mode)\nrecords {n_rec}\nduration_s {duration_s:.6f}\n"
        f"offered_rps {offered:.3f}\nmean_n {mean_n:.6f}\nmean_m {mean_m:.6f}"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    g = sub.add_parser("gen", help="generate the synthetic scenario trace")
    g.add_argument("--out", required=True)
    g.add_argument("--requests", type=int, default=1_000_000)
    g.add_argument("--load", type=float, default=96.0)
    g.add_argument("--seed", type=int, default=20220315)
    g.add_argument("--exec-noise", type=float, default=0.0)
    g.set_defaults(fn=cmd_gen)
    i = sub.add_parser("info", help="validate + summarize a trace")
    i.add_argument("file")
    i.set_defaults(fn=cmd_info)
    args = ap.parse_args()
    try:
        args.fn(args)
    except TraceError as e:
        print(f"error: trace: {e}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
