#!/usr/bin/env python3
"""Pure-python float-exact mirror of `cnmt experiment scenario`.

Replays one declarative scenario spec (SLO service classes, diurnal +
flash-crowd load shape, correlated drift, fault timeline) under the
class-blind FIFO baseline and the EDF + class-aware-hedging treatment,
and writes the same `scenario_sweep.json` the rust driver produces —
byte-for-byte.

Mirrored rust surfaces (same op order — exact floats):

  * `experiments::load::synth_shaped_workload` — non-homogeneous
    Poisson arrivals over `LoadShape::rate` (sinusoid + spikes), the
    classic per-request draw sequence;
  * `sim::scenario::run_scenario_engine` — class tagging (largest
    deficit), the per-arrival event loop, hedge-bar scaling, per-class
    accounting and conservation;
  * `scheduler::queue::FairQueue` — smooth weighted round-robin with
    per-tenant quotas and the EDF within-tenant extraction;
  * `scheduler::dispatch` — the fair front-end pump (pass-through
    depth 32), hedged submissions across arbitrary lane pairs, lazy
    ghost purge, batching, completion resolution;
  * `scheduler::capacity::BatchCost` — the opt-in batch-aware wait
    discount (per-batch-size EWMA ratio, warmup 16, floor 0.125);
  * `experiments::scenario` — the two-discipline sweep, the headline
    ratios, and the report JSON layout.

Keep this file in lockstep with the rust sources. When both toolchains
are available, `cnmt experiment scenario --out reports` and this script
must agree (bit-for-bit up to libm rounding).

Usage:
    python3 python/tools/scenario_mirror.py [--out reports/scenario_sweep.json]
    python3 python/tools/scenario_mirror.py --spec examples/scenarios/slo_mix.json
    python3 python/tools/scenario_mirror.py --requests 2500   # smoke
"""

import argparse
import heapq
import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from load_sweep_mirror import (  # noqa: E402
    EXEC_NOISE_STD,
    LOOKAHEAD,
    M_NOISE_STD,
    MAX_BATCH,
    MAX_QUEUE_DEPTH,
    MEAN_N,
    N2M_DELTA,
    N2M_GAMMA,
    N_MAX,
    RTT_S,
    TTX_ALPHA,
    TTX_PRIOR,
    TTX_REFRESH_S,
    BATCH_RESIDUAL,
    BUCKET_WIDTH,
    CLOUD_PLANE,
    EDGE_PLANE,
    HedgeBudget,
    Histogram,
    RequestTruth,
    Rng,
    TtxEstimator,
    _round_half_away,
    n2m_predict,
    texe_estimate,
    write_json,
)
from fleet_sweep_mirror import (  # noqa: E402
    CLOUD,
    EDGE,
    Phases,
    fleet_drift_factor_at,
    topo_preset,
)

# Copy-state / completion-kind tags (scheduler::dispatch mirror).
QUEUED, RUNNING, DONE, CANCELLED = 0, 1, 2, 3
SOLO, WIN, LOSS = 0, 1, 2

# scheduler::dispatch::FAIR_PASS_DEPTH.
FAIR_PASS_DEPTH = 32

# scheduler::capacity batch-aware model constants.
BATCH_COST_BINS = 8
BATCH_COST_ALPHA = 0.1
BATCH_COST_MIN_OBS = 16
BATCH_COST_MIN_DISCOUNT = 0.125


# ---------------------------------------------------------------- spec

def default_spec():
    """Mirror of experiments::scenario::default_scenario_spec (kept in
    lockstep with examples/scenarios/slo_mix.json)."""
    return {
        "name": "slo_mix",
        "topology": "hetero",
        "seed": 20220315,
        "requests": 20000,
        "load": {
            "base_rps": 260.0,
            "period_s": 30.0,
            "amplitude": 0.4,
            "spikes": [{"start_s": 25.0, "duration_s": 12.0, "factor": 2.8}],
        },
        "classes": [
            {"name": "interactive", "deadline_s": 0.5, "share": 0.2,
             "weight": 12.0, "quota": 512, "hedge_scale": 2.0},
            {"name": "batch", "deadline_s": 2.0, "share": 0.25,
             "weight": 3.0, "quota": 512, "hedge_scale": 1.0},
            {"name": "background", "deadline_s": 30.0, "share": 0.55,
             "weight": 1.0, "quota": 512, "hedge_scale": 0.0},
        ],
        "scheduling": "edf",
        "hedge": {"margin_s": 0.012, "waste_budget": 0.08, "class_aware": True},
        "drifts": [
            {"device": "cloud", "lane": None, "start_s": 40.0,
             "ramp_s": 15.0, "factor": 1.5},
        ],
        "faults": [
            {"lane": 0, "mode": "slow", "factor": 2.5,
             "start_s": 30.0, "recover_s": 45.0},
        ],
        "batch_aware_wait": True,
    }


def load_spec(path):
    """Parse a spec file into the normalized dict shape (defaults filled
    the way ScenarioSpec::from_json fills them)."""
    with open(path) as f:
        j = json.load(f)
    load = j["load"]
    spec = {
        "name": j["name"],
        "topology": j["topology"],
        "seed": int(j["seed"]),
        "requests": int(j["requests"]),
        "load": {
            "base_rps": float(load["base_rps"]),
            "period_s": float(load.get("period_s", 60.0)),
            "amplitude": float(load.get("amplitude", 0.0)),
            "spikes": [
                {"start_s": float(s["start_s"]),
                 "duration_s": float(s["duration_s"]),
                 "factor": float(s["factor"])}
                for s in load.get("spikes", [])
            ],
        },
        "classes": [
            {"name": c["name"],
             "deadline_s": float(c["deadline_s"]),
             "share": float(c["share"]),
             "weight": float(c.get("weight", 1.0)),
             "quota": int(c["quota"]),
             "hedge_scale": float(c.get("hedge_scale", 1.0))}
            for c in j["classes"]
        ],
        "scheduling": j["scheduling"],
        "hedge": None,
        "drifts": [
            {"device": d["device"],
             "lane": int(d["lane"]) if d.get("lane") is not None else None,
             "start_s": float(d["start_s"]),
             "ramp_s": float(d["ramp_s"]),
             "factor": float(d["factor"])}
            for d in j.get("drifts", [])
        ],
        "faults": [
            {"lane": int(f["lane"]), "mode": f["mode"],
             "factor": float(f["factor"]),
             "start_s": float(f["start_s"]),
             "recover_s": float(f["recover_s"])}
            for f in j.get("faults", [])
        ],
        "batch_aware_wait": bool(j.get("batch_aware_wait", False)),
    }
    if j.get("hedge") is not None:
        h = j["hedge"]
        spec["hedge"] = {
            "margin_s": float(h["margin_s"]),
            "waste_budget": float(h.get("waste_budget", 0.0)),
            "class_aware": bool(h.get("class_aware", False)),
        }
    return spec


def spec_to_json(spec):
    """Mirror of ScenarioSpec::to_json (keys are sorted at write time,
    so insertion order is irrelevant; `hedge` appears only when set,
    a drift's `lane` only when pinned)."""
    out = {
        "name": spec["name"],
        "topology": spec["topology"],
        "seed": float(spec["seed"]),
        "requests": float(spec["requests"]),
        "load": {
            "base_rps": spec["load"]["base_rps"],
            "period_s": spec["load"]["period_s"],
            "amplitude": spec["load"]["amplitude"],
            "spikes": [
                {"start_s": s["start_s"], "duration_s": s["duration_s"],
                 "factor": s["factor"]}
                for s in spec["load"]["spikes"]
            ],
        },
        "classes": [
            {"name": c["name"], "deadline_s": c["deadline_s"],
             "share": c["share"], "weight": c["weight"],
             "quota": float(c["quota"]), "hedge_scale": c["hedge_scale"]}
            for c in spec["classes"]
        ],
        "scheduling": spec["scheduling"],
        "drifts": [],
        "faults": [],
        "batch_aware_wait": spec["batch_aware_wait"],
    }
    if spec["hedge"] is not None:
        out["hedge"] = {
            "margin_s": spec["hedge"]["margin_s"],
            "waste_budget": spec["hedge"]["waste_budget"],
            "class_aware": spec["hedge"]["class_aware"],
        }
    for d in spec["drifts"]:
        dj = {"device": d["device"], "start_s": d["start_s"],
              "ramp_s": d["ramp_s"], "factor": d["factor"]}
        if d["lane"] is not None:
            dj["lane"] = float(d["lane"])
        out["drifts"].append(dj)
    for f in spec["faults"]:
        fj = {"lane": float(f["lane"]), "mode": f["mode"],
              "start_s": f["start_s"]}
        fj["recover_s"] = f["recover_s"] if math.isfinite(f["recover_s"]) else float("nan")
        fj["factor"] = f["factor"]
        out["faults"].append(fj)
    return out


def baseline_variant(spec):
    """Class-blind FIFO baseline: scheduling + class-aware scaling off,
    everything else identical."""
    s = dict(spec)
    s["scheduling"] = "fifo"
    if s["hedge"] is not None:
        h = dict(s["hedge"])
        h["class_aware"] = False
        s["hedge"] = h
    return s


def treatment_variant(spec):
    s = dict(spec)
    s["scheduling"] = "edf"
    return s


def interactive_class(spec):
    """Smallest SLO, lowest index on ties."""
    best = 0
    for k, c in enumerate(spec["classes"]):
        if c["deadline_s"] < spec["classes"][best]["deadline_s"]:
            best = k
    return best


# ---------------------------------------------------------------- workload

def shape_rate(shape, t_s):
    """Mirror of LoadShape::rate."""
    r = shape["base_rps"]
    if shape["amplitude"] > 0.0:
        r *= 1.0 + shape["amplitude"] * math.sin(
            2.0 * math.pi * t_s / shape["period_s"]
        )
    for s in shape["spikes"]:
        if s["start_s"] <= t_s < s["start_s"] + s["duration_s"]:
            r *= s["factor"]
    return r


def synth_shaped_workload(seed, count, shape):
    """Mirror of experiments::load::synth_shaped_workload — identical
    draw sequence to synth_workload, with the inter-arrival rate read
    from the shape at the current clock."""
    rng = Rng(seed)
    requests = []
    t = 0.0
    for _ in range(count):
        t += rng.exponential(shape_rate(shape, t))
        n = 1 + min(int(rng.exponential(1.0 / MEAN_N)), N_MAX - 1)
        m_mean = N2M_GAMMA * n + N2M_DELTA
        m = _round_half_away(m_mean + rng.normal_ms(0.0, M_NOISE_STD))
        m = int(min(max(m, 1.0), float(N_MAX)))
        noise_e = max(1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD), 0.2)
        noise_c = max(1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD), 0.2)
        requests.append(
            RequestTruth(
                n,
                m,
                t,
                texe_estimate(EDGE_PLANE, n, m) * noise_e,
                texe_estimate(CLOUD_PLANE, n, m) * noise_c,
                RTT_S,
                RTT_S,
            )
        )
    return requests


# ---------------------------------------------------------------- ground truth

def fault_exec_factor_at(f, lane, t_s):
    """Mirror of FaultSpec::exec_factor_at (slow faults only in v1)."""
    if f["mode"] == "slow" and lane == f["lane"] \
            and f["start_s"] <= t_s < f["recover_s"]:
        return f["factor"]
    return 1.0


def fault_link_factor_at(f, lane, t_s):
    """Mirror of FaultSpec::link_factor_at."""
    if f["mode"] == "link" and lane == f["lane"] \
            and f["start_s"] <= t_s < f["recover_s"]:
        return f["factor"]
    return 1.0


def drift_applies_to(d, tier, lane):
    """Mirror of DriftSpec::applies_to."""
    if d["lane"] is not None:
        return d["lane"] == lane
    return d["device"] == tier


# ---------------------------------------------------------------- scheduler

class BatchCost:
    """Mirror of scheduler::capacity::BatchCost."""

    def __init__(self):
        self.ratio = [1.0] * BATCH_COST_BINS
        self.obs = [0] * BATCH_COST_BINS
        self.mean_size = 1.0
        self.total_obs = 0

    def observe(self, size, est_sum_s, service_s):
        if size == 0 or not (est_sum_s > 0.0) or not math.isfinite(service_s) \
                or service_s < 0.0:
            return
        r = min(max(service_s / est_sum_s, 0.0), 4.0)
        b = min(size, BATCH_COST_BINS) - 1
        if self.obs[b] == 0:
            self.ratio[b] = r
        else:
            self.ratio[b] += BATCH_COST_ALPHA * (r - self.ratio[b])
        self.obs[b] += 1
        if self.total_obs == 0:
            self.mean_size = float(size)
        else:
            self.mean_size += BATCH_COST_ALPHA * (size - self.mean_size)
        self.total_obs += 1

    def discount(self):
        if self.total_obs < BATCH_COST_MIN_OBS:
            return 1.0
        b = int(min(max(_round_half_away(self.mean_size), 1.0),
                    float(BATCH_COST_BINS))) - 1
        if self.obs[b] == 0:
            return 1.0
        return min(max(self.ratio[b], BATCH_COST_MIN_DISCOUNT), 1.0)


class FairTenant:
    __slots__ = ("items", "deadlines", "weight", "quota", "credit")

    def __init__(self, weight, quota):
        self.items = []
        self.deadlines = []
        self.weight = weight
        self.quota = quota
        self.credit = 0.0


class FairQueue:
    """Mirror of scheduler::queue::FairQueue in EDF mode (the engine
    only builds the front-end for the EDF discipline)."""

    def __init__(self, tenants):
        self.lanes = [FairTenant(w, q) for (w, q) in tenants]

    def offer_deadline(self, tenant, rq, deadline_s):
        lane = self.lanes[tenant]
        if len(lane.items) >= lane.quota:
            return False
        lane.items.append(rq)
        lane.deadlines.append(deadline_s)
        return True

    def pop(self):
        total = 0.0
        for lane in self.lanes:
            if lane.items:
                total += lane.weight
        if total == 0.0:
            return None
        winner = None
        best = -math.inf
        for i, lane in enumerate(self.lanes):
            if not lane.items:
                continue
            lane.credit += lane.weight
            if lane.credit > best:
                best = lane.credit
                winner = i
        lane = self.lanes[winner]
        lane.credit -= total
        # Earliest deadline wins; strict < keeps arrival order on ties.
        best_i = 0
        best_d = lane.deadlines[0]
        for i in range(1, len(lane.items)):
            d = lane.deadlines[i]
            if d < best_d:
                best_d = d
                best_i = i
        del lane.deadlines[best_i]
        return lane.items.pop(best_i)


class ScenLane:
    """AdmissionQueue + CapacityTracker (+ optional FairQueue front-end
    and BatchCost model) for one scenario device."""

    def __init__(self, workers, batch_aware):
        self.items = []
        self.dead = 0
        self.peak_depth = 0
        self.free_at = [0.0] * workers
        self.backlog_est_s = 0.0
        self.cost = BatchCost() if batch_aware else None
        self.fair = None
        self.down = False

    def queue_has_room(self):
        return len(self.items) - self.dead < MAX_QUEUE_DEPTH

    def has_room(self):
        return not self.down and self.queue_has_room()

    def queue_offer(self, rq):
        """AdmissionQueue::offer — no capacity accounting (the fair pump
        uses this directly; pumping is accounting-neutral)."""
        if not self.queue_has_room():
            return False
        self.items.append(rq)
        self.peak_depth = max(self.peak_depth, len(self.items) - self.dead)
        return True

    def offer(self, rq):
        """Lane::offer — admit + account in one step."""
        if self.down:
            return False
        if not self.queue_offer(rq):
            return False
        self.backlog_est_s += max(rq[4], 0.0)
        return True

    def on_admit(self, est):
        self.backlog_est_s += max(est, 0.0)

    def on_cancel(self, est):
        self.backlog_est_s = max(self.backlog_est_s - max(est, 0.0), 0.0)

    def pump_fair(self):
        """Drain the fair front-end into the dispatch queue up to the
        pass-through depth (capacity was accounted at front-end
        admission)."""
        if self.fair is None:
            return
        while len(self.items) - self.dead < FAIR_PASS_DEPTH \
                and self.queue_has_room():
            rq = self.fair.pop()
            if rq is None:
                return
            self.queue_offer(rq)

    def earliest_free(self):
        best_i, best_t = 0, self.free_at[0]
        for i in range(1, len(self.free_at)):
            if self.free_at[i] < best_t:
                best_i, best_t = i, self.free_at[i]
        return best_i, best_t

    def expected_wait_s(self, now_s):
        inflight = 0.0
        for t in self.free_at:
            if t > now_s:
                inflight += t - now_s
        if self.cost is not None:
            return (inflight + self.backlog_est_s * self.cost.discount()) \
                / len(self.free_at)
        return (inflight + self.backlog_est_s) / len(self.free_at)


def bucket_of(m_est):
    """Mirror of BatchPolicy::bucket_of."""
    return int(max(m_est, 0.0) / BUCKET_WIDTH)


class ScenDispatcher:
    """Mirror of the N-lane scheduler::Dispatcher with the fair EDF
    front-end and the batch-aware capacity model. QueuedRequest tuples:
    (id, payload, n, m_est, est_service_s, arrival_s, bucket, hedge)."""

    def __init__(self, tiers, workers, batch_aware):
        self.tiers = tiers
        self.lanes = [ScenLane(w, batch_aware) for w in workers]
        self.batches = 0
        self.batch_requests = 0
        self.pending = []
        self.seq = 0
        self.arena = []
        self.arena_free = []
        self.hs_hedged = 0
        self.hs_wins_edge = 0
        self.hs_wins_cloud = 0
        self.hs_cancelled = 0
        self.hs_losers = 0

    def enable_fair_tenants(self, tenants):
        for lane in self.lanes:
            lane.fair = FairQueue(tenants)

    def arena_alloc(self, entry):
        if self.arena_free:
            idx = self.arena_free.pop()
            self.arena[idx] = entry
            return idx
        self.arena.append(entry)
        return len(self.arena) - 1

    def arena_release(self, idx):
        self.arena[idx] = None
        self.arena_free.append(idx)

    def submit_lane(self, lane, rq):
        rq = rq[:6] + (bucket_of(rq[3]), None)
        return self.lanes[lane].offer(rq)

    def submit_lane_tenant_deadline(self, lane, tenant, rq, deadline_s):
        rq = rq[:6] + (bucket_of(rq[3]), None)
        l = self.lanes[lane]
        if l.fair is None:
            return l.offer(rq)
        admitted = l.fair.offer_deadline(tenant, rq, deadline_s)
        if admitted:
            # The capacity view must include front-end backlog: account
            # here, not at pass-through.
            l.on_admit(rq[4])
            l.pump_fair()
        return admitted

    def submit_hedged_lanes(self, rq, lane_a, est_a, lane_b, est_b):
        rq = rq[:6] + (bucket_of(rq[3]), None)
        if self.lanes[lane_a].has_room() and self.lanes[lane_b].has_room():
            idx = self.arena_alloc(
                [lane_a, lane_b, est_a, est_b, QUEUED, QUEUED, None]
            )
            a_rq = rq[:4] + (est_a,) + rq[5:7] + (idx,)
            b_rq = rq[:4] + (est_b,) + rq[5:7] + (idx,)
            self.lanes[lane_a].offer(a_rq)
            self.lanes[lane_b].offer(b_rq)
            self.hs_hedged += 1
            return "hedged"
        a_rq = rq[:4] + (est_a,) + rq[5:]
        b_rq = rq[:4] + (est_b,) + rq[5:]
        a_ok = self.lanes[lane_a].offer(a_rq)
        b_ok = self.lanes[lane_b].offer(b_rq)
        if a_ok:
            return ("single", lane_a)
        if b_ok:
            return ("single", lane_b)
        return "rejected"

    def _ghost_side(self, entry, lane):
        return 4 if entry[0] == lane else 5

    def lane_next_start(self, li):
        lane = self.lanes[li]
        if lane.down:
            return None
        lane.pump_fair()
        arena = self.arena
        while True:
            if not lane.items:
                return None
            head = lane.items[0]
            hid = head[7]
            if hid is not None and \
                    arena[hid][self._ghost_side(arena[hid], li)] == CANCELLED:
                lane.items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
                continue
            _w, free_s = lane.earliest_free()
            return max(free_s, head[5])

    def next_batch_start(self):
        best = None
        for li in range(len(self.lanes)):
            s = self.lane_next_start(li)
            if s is None:
                continue
            # Strict < keeps the lowest lane index on ties.
            if best is None or s < best[1]:
                best = (li, s)
        return best

    def expected_wait_lane(self, lane, now_s):
        return self.lanes[lane].expected_wait_s(now_s)

    def form_batch(self, lane, li, start_s):
        items = lane.items
        arena = self.arena
        while True:
            if not items:
                return []
            head = items[0]
            hid = head[7]
            if hid is not None and \
                    arena[hid][self._ghost_side(arena[hid], li)] == CANCELLED:
                items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
            else:
                break
        head = items.pop(0)
        bucket = head[6]
        batch = [head]
        i = 0
        scanned = 0
        while len(batch) < MAX_BATCH and scanned < LOOKAHEAD:
            if i >= len(items):
                break
            rq = items[i]
            hid = rq[7]
            if hid is not None and \
                    arena[hid][self._ghost_side(arena[hid], li)] == CANCELLED:
                del items[i]
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
                continue
            if rq[6] == bucket and rq[5] <= start_s:
                batch.append(rq)
                del items[i]
            else:
                i += 1
            scanned += 1
        return batch

    def dispatch_at(self, li, start_s, exec_fn):
        lane = self.lanes[li]
        batch = self.form_batch(lane, li, start_s)
        if not batch:
            return
        for rq in batch:
            if rq[7] is not None:
                entry = self.arena[rq[7]]
                entry[self._ghost_side(entry, li)] = RUNNING
        est_sum = 0.0
        for rq in batch:
            est_sum += rq[4]
        service_s = max(exec_fn(li, batch, start_s), 0.0)
        done_s = start_s + service_s
        worker, _free = lane.earliest_free()
        lane.backlog_est_s = max(lane.backlog_est_s - est_sum, 0.0)
        lane.free_at[worker] = done_s
        if lane.cost is not None:
            lane.cost.observe(len(batch), est_sum, service_s)
        self.batches += 1
        self.batch_requests += len(batch)
        bsize = len(batch)
        for rq in batch:
            heapq.heappush(self.pending, (done_s, self.seq, start_s, bsize, li, rq))
            self.seq += 1

    def resolve_completion(self, li, hid):
        if hid is None:
            return SOLO
        entry = self.arena[hid]
        side = 0 if entry[0] == li else 1
        entry[4 + side] = DONE
        if entry[6] is not None:
            self.arena_release(hid)
            self.hs_losers += 1
            return LOSS
        entry[6] = side
        if self.tiers[li] == EDGE:
            self.hs_wins_edge += 1
        else:
            self.hs_wins_cloud += 1
        twin = 1 - side
        if entry[4 + twin] == QUEUED:
            entry[4 + twin] = CANCELLED
            self.hs_cancelled += 1
            twin_lane = entry[twin]
            self.lanes[twin_lane].on_cancel(entry[2 + twin])
            self.lanes[twin_lane].dead += 1
        return WIN

    def flush_one(self, out):
        done_s, _seq, start_s, bsize, li, rq = heapq.heappop(self.pending)
        kind = self.resolve_completion(li, rq[7])
        out.append((rq, li, start_s, done_s, bsize, kind))

    def step(self, horizon_s, exec_fn, out):
        ns = self.next_batch_start()
        nd = self.pending[0][0] if self.pending else None
        if ns is None and nd is None:
            return False
        completion_first = ns is None or (nd is not None and nd <= ns[1])
        if completion_first:
            if nd > horizon_s:
                return False
            self.flush_one(out)
        else:
            li, start_s = ns
            if start_s > horizon_s:
                return False
            self.dispatch_at(li, start_s, exec_fn)
        return True

    def run_until(self, horizon_s, exec_fn, out):
        while self.step(horizon_s, exec_fn, out):
            pass


# ---------------------------------------------------------------- engine

class ClassAssigner:
    """Mirror of sim::scenario::ClassAssigner (largest deficit)."""

    def __init__(self, shares):
        self.shares = shares
        self.assigned = [0] * len(shares)
        self.seen = 0

    def next(self):
        target = float(self.seen + 1)
        best = 0
        best_deficit = self.shares[0] * target - self.assigned[0]
        for k in range(1, len(self.shares)):
            deficit = self.shares[k] * target - self.assigned[k]
            if deficit > best_deficit:
                best = k
                best_deficit = deficit
        self.assigned[best] += 1
        self.seen += 1
        return best


class OnlineStats:
    """Mirror of metrics::OnlineStats (Welford)."""

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self.sum = 0.0
        self.m2 = 0.0

    def push(self, x):
        self.n += 1
        self.sum += x
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    def mean_value(self):
        return self.mean if self.n else float("nan")


class ScenSelector:
    """FleetSelector with no health/refit surface — the scenario engine
    runs Select with the shared T_tx estimator only."""

    def __init__(self, topo):
        devs = topo["devices"]
        self.tiers = [d["tier"] for d in devs]
        self.link_scale = [d["link_scale"] for d in devs]
        self.texe = []
        for d in devs:
            base = EDGE_PLANE if d["tier"] == EDGE else CLOUD_PLANE
            slow = 1.0 / d["speed"]
            self.texe.append((base[0] * slow, base[1] * slow, base[2] * slow))
        self.edge_ids = [i for i, t in enumerate(self.tiers) if t == EDGE]
        self.cloud_ids = [i for i, t in enumerate(self.tiers) if t == CLOUD]
        self.ttx = TtxEstimator(TTX_ALPHA)

    def best_of(self, ids, n, m_est, ttx_est, waits):
        best_d, best_score, best_est = -1, math.inf, math.inf
        for d in ids:
            est = texe_estimate(self.texe[d], n, m_est)
            if self.tiers[d] == EDGE:
                score = est + waits[d]
            else:
                score = ttx_est * self.link_scale[d] + est + waits[d]
            if score < best_score:
                best_d, best_score, best_est = d, score, est
        return best_d, best_score, best_est

    def select(self, n, waits):
        m_est = n2m_predict(N2M_GAMMA, N2M_DELTA, n)
        ttx_est = self.ttx.estimate_or(TTX_PRIOR)
        be = self.best_of(self.edge_ids, n, m_est, ttx_est, waits)
        bc = self.best_of(self.cloud_ids, n, m_est, ttx_est, waits)
        best = be if be[1] <= bc[1] else bc
        return {
            "device": best[0],
            "m_est": m_est,
            "est": best[2],
            "best_edge": be,
            "best_cloud": bc,
        }


def run_scenario_engine(requests, topo, spec):
    """Mirror of sim::scenario::run_scenario_engine (recorder off)."""
    devs = topo["devices"]
    n_dev = len(devs)
    tiers = [d["tier"] for d in devs]
    slowdown = [1.0 / d["speed"] for d in devs]
    link_scale = [d["link_scale"] for d in devs]
    drifts = spec["drifts"]
    faults = spec["faults"]
    classes = spec["classes"]
    k_classes = len(classes)

    sel = ScenSelector(topo)
    disp = ScenDispatcher(
        tiers, [d["workers"] for d in devs], spec["batch_aware_wait"]
    )
    if spec["scheduling"] == "edf":
        disp.enable_fair_tenants([(c["weight"], c["quota"]) for c in classes])
    hedge = spec["hedge"]
    ctl = None
    if hedge is not None and hedge["waste_budget"] > 0.0:
        ctl = HedgeBudget(hedge["waste_budget"], hedge["margin_s"])

    def true_service_s(truth, lane, start_s):
        base = truth.t_edge if tiers[lane] == EDGE else truth.t_cloud
        t = base * slowdown[lane]
        for d in drifts:
            if drift_applies_to(d, tiers[lane], lane):
                t *= fleet_drift_factor_at(d, start_s)
        for f in faults:
            t *= fault_exec_factor_at(f, lane, start_s)
        return t

    def exec_fn(li, batch, start_s):
        mx = 0.0
        sm = 0.0
        for rq in batch:
            t = true_service_s(requests[rq[1]], li, start_s)
            if t > mx:
                mx = t
            sm += t
        return mx + (sm - mx) * BATCH_RESIDUAL

    # Accounting (mirror of ScenarioAcct).
    hist = Histogram()
    stats = OnlineStats()
    edge_count = cloud_count = completed = 0
    last_done_s = 0.0
    useful_work_s = wasted_work_s = 0.0
    device_results = [0] * n_dev
    class_hist = [Histogram() for _ in range(k_classes)]
    class_stats = [OnlineStats() for _ in range(k_classes)]
    class_completed = [0] * k_classes
    class_within = [0] * k_classes
    class_phases = [Phases() for _ in range(k_classes)]

    assigner = ClassAssigner([c["share"] for c in classes])
    class_of = [0] * len(requests)
    class_offered = [0] * k_classes
    class_shed = [0] * k_classes
    class_hedged = [0] * k_classes
    waits = [0.0] * n_dev
    rejected = 0
    comps = []

    def process(batch):
        nonlocal edge_count, cloud_count, completed, last_done_s
        nonlocal useful_work_s, wasted_work_s
        for (rq, li, start_s, done_s, _bsize, kind) in batch:
            truth = requests[rq[1]]
            t_true = true_service_s(truth, li, start_s)
            if tiers[li] == EDGE:
                tx_s = 0.0
            else:
                tx_s = truth.t_tx * link_scale[li]
                # A response transfers at completion time: it pays the
                # link state the fault timeline says is live *then*.
                for f in faults:
                    tx_s *= fault_link_factor_at(f, li, done_s)
            if kind == LOSS:
                wasted_work_s += t_true
                if ctl is not None:
                    ctl.observe(t_true, True)
                continue
            useful_work_s += t_true
            if ctl is not None:
                ctl.observe(t_true, False)
            k = class_of[rq[1]]
            class_phases[k].record(
                start_s - rq[5],
                (done_s - start_s) - t_true,
                t_true,
                tx_s,
            )
            latency = (done_s - rq[5]) + tx_s
            hist.record(latency)
            stats.push(latency)
            class_hist[k].record(latency)
            class_stats[k].push(latency)
            class_completed[k] += 1
            if latency <= classes[k]["deadline_s"]:
                class_within[k] += 1
            if tiers[li] == EDGE:
                edge_count += 1
            else:
                cloud_count += 1
            completed += 1
            device_results[li] += 1
            last_done_s = max(last_done_s, done_s + tx_s)

    for i, rq in enumerate(requests):
        now = rq.arrival_s
        comps.clear()
        disp.run_until(now, exec_fn, comps)
        process(comps)
        klass = assigner.next()
        class_of[i] = klass
        class_offered[klass] += 1
        # Gateway heartbeat keeps the shared T_tx fresh.
        if sel.ttx.is_stale(now, TTX_REFRESH_S):
            sel.ttx.observe(now, rq.rtt)
        for d in range(n_dev):
            waits[d] = disp.expected_wait_lane(d, now)
        trace = sel.select(rq.n, waits)
        queued = (i, i, rq.n, trace["m_est"], 0.0, now, 0, None)
        do_hedge = False
        if hedge is not None:
            bar = ctl.margin_s if ctl is not None else hedge["margin_s"]
            if hedge["class_aware"]:
                bar = bar * classes[klass]["hedge_scale"]
            margin = trace["best_edge"][1] - trace["best_cloud"][1]
            do_hedge = bar > 0.0 and math.isfinite(margin) and abs(margin) <= bar
        if do_hedge:
            outcome = disp.submit_hedged_lanes(
                queued,
                trace["best_edge"][0],
                trace["best_edge"][2],
                trace["best_cloud"][0],
                trace["best_cloud"][2],
            )
            if outcome == "hedged":
                cloud_in_flight = True
            elif outcome == "rejected":
                cloud_in_flight = False
            else:
                cloud_in_flight = tiers[outcome[1]] == CLOUD
            if cloud_in_flight:
                sel.ttx.observe(now, rq.rtt)
            if outcome == "hedged":
                class_hedged[klass] += 1
                copies = 2
            elif outcome == "rejected":
                copies = 0
            else:
                copies = 1
        else:
            queued = queued[:4] + (trace["est"],) + queued[5:]
            if tiers[trace["device"]] == CLOUD:
                sel.ttx.observe(now, rq.rtt)
            if spec["scheduling"] == "edf":
                admitted = disp.submit_lane_tenant_deadline(
                    trace["device"], klass, queued,
                    now + classes[klass]["deadline_s"],
                )
            else:
                admitted = disp.submit_lane(trace["device"], queued)
            copies = 1 if admitted else 0
        if copies == 0:
            rejected += 1
            class_shed[klass] += 1

    # Drain: open-loop arrivals have ended; finish the backlog.
    comps.clear()
    disp.run_until(math.inf, exec_fn, comps)
    process(comps)
    for k in range(k_classes):
        assert class_offered[k] == class_shed[k] + class_completed[k], \
            f"class `{classes[k]['name']}` leaked requests"

    first_arrival_s = requests[0].arrival_s if requests else 0.0
    makespan_s = max(last_done_s - first_arrival_s, 0.0)
    class_rows = []
    for k, c in enumerate(classes):
        attainment = class_within[k] / class_offered[k] \
            if class_offered[k] else 0.0
        class_rows.append({
            "name": c["name"],
            "deadline_s": c["deadline_s"],
            "offered": float(class_offered[k]),
            "shed": float(class_shed[k]),
            "completed": float(class_completed[k]),
            "within_deadline": float(class_within[k]),
            "attainment": attainment,
            "hedged": float(class_hedged[k]),
            "mean_latency_s": class_stats[k].mean_value(),
            "p50_s": class_hist[k].quantile(0.50),
            "p95_s": class_hist[k].quantile(0.95),
            "p99_s": class_hist[k].quantile(0.99),
            "phases": class_phases[k].to_json(),
        })
    result = {
        "scenario": spec["name"],
        "scheduling": spec["scheduling"],
        "offered": float(len(requests)),
        "completed": float(completed),
        "rejected": float(rejected),
        "edge_count": float(edge_count),
        "cloud_count": float(cloud_count),
        "makespan_s": makespan_s,
        "throughput_rps": completed / makespan_s if makespan_s > 0.0 else 0.0,
        "mean_latency_s": stats.mean_value(),
        "p50_s": hist.quantile(0.50),
        "p95_s": hist.quantile(0.95),
        "p99_s": hist.quantile(0.99),
        "mean_batch": disp.batch_requests / disp.batches
        if disp.batches else float("nan"),
        "hedged": float(disp.hs_hedged),
        "hedge_wins_edge": float(disp.hs_wins_edge),
        "hedge_wins_cloud": float(disp.hs_wins_cloud),
        "hedge_cancelled": float(disp.hs_cancelled),
        "hedge_wasted": float(disp.hs_losers),
        "useful_work_s": useful_work_s,
        "wasted_work_s": wasted_work_s,
        "device_results": [float(c) for c in device_results],
        "peak_depths": [float(l.peak_depth) for l in disp.lanes],
        "classes": class_rows,
    }
    if ctl is not None and math.isfinite(ctl.margin_s):
        result["hedge_final_margin_s"] = ctl.margin_s
    return result


# ---------------------------------------------------------------- driver

def run_sweep(spec):
    """Mirror of experiments::scenario::run — one workload, two
    discipline cells."""
    topo = topo_preset(spec["topology"])
    requests = synth_shaped_workload(spec["seed"], spec["requests"], spec["load"])
    results = [
        run_scenario_engine(requests, topo, baseline_variant(spec)),
        run_scenario_engine(requests, topo, treatment_variant(spec)),
    ]
    return results


def sweep_to_json(spec, results):
    """Mirror of experiments::scenario::to_json."""
    k = interactive_class(spec)
    by_tag = {r["scheduling"]: r for r in results}
    fifo = by_tag["fifo"]["classes"][k]
    edf = by_tag["edf"]["classes"][k]
    fifo_missed = fifo["offered"] - fifo["within_deadline"]
    edf_missed = edf["offered"] - edf["within_deadline"]
    return {
        "spec": spec_to_json(spec),
        "interactive_class": spec["classes"][k]["name"],
        "disciplines": {r["scheduling"]: r for r in results},
        "headline_interactive_attainment": edf["attainment"],
        "headline_fifo_attainment": fifo["attainment"],
        "headline_miss_ratio": fifo_missed / max(edf_missed, 1.0),
        "headline_goodput_ratio":
            by_tag["edf"]["throughput_rps"] / by_tag["fifo"]["throughput_rps"],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="reports/scenario_sweep.json")
    ap.add_argument("--spec", default=None,
                    help="scenario spec JSON (default: built-in slo_mix)")
    ap.add_argument("--requests", type=int, default=None,
                    help="override the spec's request count (smoke runs)")
    args = ap.parse_args()

    spec = load_spec(args.spec) if args.spec else default_spec()
    if args.requests is not None:
        spec["requests"] = args.requests
    results = run_sweep(spec)
    root = sweep_to_json(spec, results)
    k = interactive_class(spec)
    for r in results:
        c = r["classes"][k]
        print(
            f"  {r['scheduling']:>4}: {spec['classes'][k]['name']} attainment "
            f"{c['attainment'] * 100.0:.1f}% "
            f"(shed {int(c['shed'])}), goodput {r['throughput_rps']:.1f} r/s"
        )
    print(
        f"  miss ratio {root['headline_miss_ratio']:.2f}x, "
        f"goodput ratio {root['headline_goodput_ratio']:.3f}x"
    )
    write_json(args.out, root)


if __name__ == "__main__":
    main()
