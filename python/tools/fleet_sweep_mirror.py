#!/usr/bin/env python3
"""Standalone mirror of `cnmt experiment fleet` (rust/src/experiments/fleet.rs).

Why this exists: like `load_sweep_mirror.py`, the fleet-sweep report
checked in under `reports/` must be regenerable in environments with no
rust toolchain, and the fleet dynamics need a second, independent
implementation to validate against. This script re-implements, operation
for operation, exactly what the rust driver does:

  * `fleet::topology`            — the device specs (tier, speed factor,
                                   workers, link scale) and the built-in
                                   presets (1x1 / 4x2 / 8x4 / hetero);
  * `fleet::select`              — eq. 1 scored over every placement
                                   (edge: T̂_exe·slow + Ŵ; cloud:
                                   T̂_tx·link + T̂_exe·slow + Ŵ), arg-min
                                   with lowest-id ties and the pair
                                   router's `≤` on the edge/cloud tie;
  * `scheduler::dispatch`        — the N-lane generalisation of the
                                   two-lane event loop: one ring-buffer
                                   queue + capacity tracker per lane,
                                   lowest lane index winning start-time
                                   ties, hedge races spanning arbitrary
                                   lane pairs via arena entries that
                                   record their two lanes;
  * `sim::harness::run_fleet`    — the open-loop replay: heartbeat +
                                   timestamped T_tx observations, blind
                                   round-robin / seeded-random replica
                                   baselines, hedged best-edge vs
                                   best-cloud placement, per-device
                                   result accounting, link-scaled
                                   network charging;
  * `experiments::fleet`         — the shape grid, per-shape workload
                                   seeding via `util::rng::cell_seed`,
                                   and the report JSON layout.

On every run the script first re-proves the 1×1 anchor: the fleet path
on the pair topology must reproduce `load_sweep_mirror.run_contended`
float-for-float (blind ≡ cnmt, select ≡ cnmt+queue, hedge ≡ the
adaptive configuration with the RLS refit disabled) — the same
differential the rust test suite runs against `run_contended`.

Keep this file in lockstep with the rust sources. When both toolchains
are available, `cnmt experiment fleet --out reports` and this script
must agree (bit-for-bit up to libm rounding).

Usage:
    python3 python/tools/fleet_sweep_mirror.py [--out reports/fleet_sweep.json]
    python3 python/tools/fleet_sweep_mirror.py --shapes 1x1,4x2 --requests 5000
"""

import argparse
import heapq
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from load_sweep_mirror import (  # noqa: E402
    ADAPTIVE_DEFAULTS,
    BATCH_RESIDUAL,
    BUCKET_WIDTH,
    CLOUD_PLANE,
    EDGE_PLANE,
    LOOKAHEAD,
    MASK,
    MAX_BATCH,
    MAX_QUEUE_DEPTH,
    N2M_DELTA,
    N2M_GAMMA,
    SEED,
    TTX_ALPHA,
    TTX_PRIOR,
    TTX_REFRESH_S,
    HedgeBudget,
    Histogram,
    Rls,
    Rls2,
    Rng,
    TtxEstimator,
    n2m_predict,
    run_closed_loop,
    run_contended,
    synth_workload,
    texe_estimate,
    to_json_value,
    write_json,
)

EDGE, CLOUD = "edge", "cloud"
QUEUED, RUNNING, DONE, CANCELLED = 0, 1, 2, 3
SOLO, WIN, LOSS = 0, 1, 2

# experiments::fleet constants.
REQUESTS_PER_POINT = 20000
FLEET_HEDGE_MARGIN_S = 0.010
RANDOM_PICK_TAG = 0xF1E37
DEFAULT_SHAPES = ["1x1", "4x2", "8x4", "hetero"]
OFFERED_RPS = {"1x1": 96.0, "4x2": 288.0, "8x4": 576.0, "hetero": 224.0}

# Closed-loop drift sweep constants (experiments::fleet).
FLEET_CLOSED_SEED_TAG = 0xFC105ED
FLEET_CLOSED_DRIFT_FACTOR = 2.5
FLEET_CLOSED_DRIFT_START_FRAC = 0.25
FLEET_CLOSED_DRIFT_RAMP_S = 10.0
FLEET_CLOSED_CLIENTS = [8, 16, 32, 64]


def fleet_drift_factor_at(drift, t_s):
    """Mirror of DriftSpec::factor_at for a lane-pinned fleet spec
    {lane, start_s, ramp_s, factor}."""
    if t_s <= drift["start_s"]:
        return 1.0
    if drift["ramp_s"] <= 0.0:
        return drift["factor"]
    frac = min((t_s - drift["start_s"]) / drift["ramp_s"], 1.0)
    return 1.0 + (drift["factor"] - 1.0) * frac


def cell_seed(master, cell):
    """Mirror of util::rng::cell_seed."""
    return (master ^ (((cell + 1) * 0x9E3779B97F4A7C15) & MASK)) & MASK


def rng_usize(rng, n):
    """Mirror of util::rng::Rng::usize (Lemire multiply-shift, debiased)."""
    threshold = ((1 << 64) - n) % n
    while True:
        x = rng.next_u64()
        m = x * n
        if (m & MASK) >= threshold:
            return m >> 64


# ---------------------------------------------------------------- topology


def device(name, tier, speed, workers, link_scale):
    return {
        "name": name,
        "tier": tier,
        "speed": speed,
        "workers": workers,
        "link_scale": link_scale,
    }


def topo_pair():
    return {
        "name": "1x1",
        "devices": [
            device("edge0", EDGE, 1.0, 1, 1.0),
            device("cloud0", CLOUD, 1.0, 4, 1.0),
        ],
    }


def topo_uniform(edges, clouds):
    devs = [device(f"edge{i}", EDGE, 1.0, 1, 1.0) for i in range(edges)]
    devs += [device(f"cloud{i}", CLOUD, 1.0, 4, 1.0) for i in range(clouds)]
    return {"name": f"{edges}x{clouds}", "devices": devs}


def topo_hetero():
    return {
        "name": "hetero",
        "devices": [
            device("edge0", EDGE, 2.0, 1, 1.0),
            device("edge1", EDGE, 1.0, 1, 1.0),
            device("edge2", EDGE, 1.0, 1, 1.0),
            device("edge3", EDGE, 0.5, 1, 1.0),
            device("cloud0", CLOUD, 1.0, 4, 1.0),
            device("cloud1", CLOUD, 0.5, 4, 1.5),
        ],
    }


def topo_preset(name):
    if name == "1x1":
        return topo_pair()
    if name == "hetero":
        return topo_hetero()
    e, _, c = name.partition("x")
    return topo_uniform(int(e), int(c))


# ---------------------------------------------------------------- N-lane dispatcher


class FleetLane:
    """AdmissionQueue + CapacityTracker for one fleet device."""

    def __init__(self, workers):
        self.items = []
        self.free_at = [0.0] * workers
        self.backlog_est_s = 0.0
        self.dead = 0
        self.peak_depth = 0
        # Fault-injection hook (mirror of scheduler::LaneHealth): a down
        # lane refuses admissions and never dispatches. Dormant (False)
        # unless a FaultSpec drives it, so every legacy report is
        # byte-identical.
        self.down = False

    def has_room(self):
        return not self.down and len(self.items) - self.dead < MAX_QUEUE_DEPTH

    def offer(self, rq):
        if not self.has_room():
            return False
        self.items.append(rq)
        self.peak_depth = max(self.peak_depth, len(self.items) - self.dead)
        self.backlog_est_s += max(rq[4], 0.0)
        return True

    def earliest_free(self):
        best_i, best_t = 0, self.free_at[0]
        for i in range(1, len(self.free_at)):
            if self.free_at[i] < best_t:
                best_i, best_t = i, self.free_at[i]
        return best_i, best_t

    def expected_wait_s(self, now_s):
        inflight = 0.0
        for t in self.free_at:
            if t > now_s:
                inflight += t - now_s
        return (inflight + self.backlog_est_s) / len(self.free_at)

    def on_cancel(self, est):
        self.backlog_est_s = max(self.backlog_est_s - max(est, 0.0), 0.0)


class FleetDispatcher:
    """Mirror of the N-lane scheduler::Dispatcher. Hedge arena entries
    record the two lanes they span: [lane_a, lane_b, est_a, est_b,
    state_a, state_b, winner_side]."""

    def __init__(self, tiers, workers):
        self.tiers = tiers
        self.lanes = [FleetLane(w) for w in workers]
        self.batches = 0
        self.batch_requests = 0
        self.pending = []
        self.seq = 0
        self.arena = []
        self.arena_free = []
        self.hs_hedged = 0
        self.hs_wins_edge = 0
        self.hs_wins_cloud = 0
        self.hs_cancelled = 0
        self.hs_losers = 0
        # Deadline-timer hooks (mirror of the rust dispatcher's retry
        # timers): `armed` is None until a harness with a retry policy
        # enables it, so the happy path never touches these.
        self.timers = []
        self.timer_seq = 0
        self.armed = None
        # Anomaly-detector tap (mirror of dispatcher.detector): fed one
        # exec residual per completion from flush_one. None = detached.
        self.detector = None

    def arena_alloc(self, entry):
        if self.arena_free:
            idx = self.arena_free.pop()
            self.arena[idx] = entry
            return idx
        self.arena.append(entry)
        return len(self.arena) - 1

    def arena_release(self, idx):
        self.arena[idx] = None
        self.arena_free.append(idx)

    def submit_lane(self, lane, rq):
        return self.lanes[lane].offer(rq)

    def submit_hedged_lanes(self, rq, lane_a, est_a, lane_b, est_b):
        if self.lanes[lane_a].has_room() and self.lanes[lane_b].has_room():
            idx = self.arena_alloc([lane_a, lane_b, est_a, est_b, QUEUED, QUEUED, None])
            a_rq = rq[:4] + (est_a,) + rq[5:7] + (idx,)
            b_rq = rq[:4] + (est_b,) + rq[5:7] + (idx,)
            self.lanes[lane_a].offer(a_rq)
            self.lanes[lane_b].offer(b_rq)
            self.hs_hedged += 1
            return "hedged"
        a_rq = rq[:4] + (est_a,) + rq[5:]
        b_rq = rq[:4] + (est_b,) + rq[5:]
        a_ok = self.lanes[lane_a].offer(a_rq)
        b_ok = self.lanes[lane_b].offer(b_rq)
        if a_ok:
            return ("single", lane_a)
        if b_ok:
            return ("single", lane_b)
        return "rejected"

    def _ghost_side(self, entry, lane):
        return 4 if entry[0] == lane else 5

    def lane_next_start(self, li):
        lane = self.lanes[li]
        if lane.down:
            return None
        arena = self.arena
        while True:
            if not lane.items:
                return None
            head = lane.items[0]
            hid = head[7]
            if hid is not None and arena[hid][self._ghost_side(arena[hid], li)] == CANCELLED:
                lane.items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
                continue
            _w, free_s = lane.earliest_free()
            return max(free_s, head[5])

    def next_batch_start(self):
        best = None
        for li in range(len(self.lanes)):
            s = self.lane_next_start(li)
            if s is None:
                continue
            # Strict < keeps the lowest lane index on ties.
            if best is None or s < best[1]:
                best = (li, s)
        return best

    def next_event_s(self):
        ns = self.next_batch_start()
        nd = self.pending[0][0] if self.pending else None
        if ns is None and nd is None:
            return None
        if ns is None:
            return nd
        if nd is None:
            return ns[1]
        return min(ns[1], nd)

    def form_batch(self, lane, li, start_s):
        items = lane.items
        arena = self.arena
        while True:
            if not items:
                return []
            head = items[0]
            hid = head[7]
            if hid is not None and arena[hid][self._ghost_side(arena[hid], li)] == CANCELLED:
                items.pop(0)
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
            else:
                break
        head = items.pop(0)
        bucket = head[6]
        batch = [head]
        i = 0
        scanned = 0
        while len(batch) < MAX_BATCH and scanned < LOOKAHEAD:
            if i >= len(items):
                break
            rq = items[i]
            hid = rq[7]
            if hid is not None and arena[hid][self._ghost_side(arena[hid], li)] == CANCELLED:
                del items[i]
                lane.dead = max(lane.dead - 1, 0)
                self.arena_release(hid)
                continue
            if rq[6] == bucket and rq[5] <= start_s:
                batch.append(rq)
                del items[i]
            else:
                i += 1
            scanned += 1
        return batch

    def dispatch_at(self, li, start_s, exec_fn):
        lane = self.lanes[li]
        batch = self.form_batch(lane, li, start_s)
        if not batch:
            return
        if self.armed is not None:
            # A dispatched request is no longer stuck in a queue: its
            # deadline timer (which only covers queue wait) is disarmed.
            for rq in batch:
                ent = self.armed.get(rq[0])
                if ent is not None and ent[1] == li:
                    del self.armed[rq[0]]
        for rq in batch:
            if rq[7] is not None:
                entry = self.arena[rq[7]]
                entry[self._ghost_side(entry, li)] = RUNNING
        est_sum = 0.0
        for rq in batch:
            est_sum += rq[4]
        service_s = max(exec_fn(li, batch, start_s), 0.0)
        done_s = start_s + service_s
        worker, _free = lane.earliest_free()
        lane.backlog_est_s = max(lane.backlog_est_s - est_sum, 0.0)
        lane.free_at[worker] = done_s
        self.batches += 1
        self.batch_requests += len(batch)
        bsize = len(batch)
        for rq in batch:
            heapq.heappush(self.pending, (done_s, self.seq, start_s, bsize, li, rq))
            self.seq += 1

    def resolve_completion(self, li, hid):
        if hid is None:
            return SOLO
        entry = self.arena[hid]
        side = 0 if entry[0] == li else 1
        entry[4 + side] = DONE
        if entry[6] is not None:
            self.arena_release(hid)
            self.hs_losers += 1
            return LOSS
        entry[6] = side
        if self.tiers[li] == EDGE:
            self.hs_wins_edge += 1
        else:
            self.hs_wins_cloud += 1
        twin = 1 - side
        if entry[4 + twin] == QUEUED:
            entry[4 + twin] = CANCELLED
            self.hs_cancelled += 1
            twin_lane = entry[twin]
            self.lanes[twin_lane].on_cancel(entry[2 + twin])
            self.lanes[twin_lane].dead += 1
        elif entry[4 + twin] == CANCELLED:
            # The twin copy was physically destroyed by a lane failure
            # (never a normal cancel: those only happen at win time):
            # the race is closed and no lazy ghost purge will ever find
            # it, so the entry is released here.
            self.arena_release(hid)
        return WIN

    def flush_one(self, out):
        done_s, _seq, start_s, bsize, li, rq = heapq.heappop(self.pending)
        kind = self.resolve_completion(li, rq[7])
        if self.detector is not None:
            self.detector.observe_exec(li, done_s, done_s - start_s, rq[4])
        out.append((rq, li, start_s, done_s, bsize, kind))

    def step(self, horizon_s, exec_fn, out):
        ns = self.next_batch_start()
        nd = self.pending[0][0] if self.pending else None
        if ns is None and nd is None:
            return False
        completion_first = ns is None or (nd is not None and nd <= ns[1])
        if completion_first:
            if nd > horizon_s:
                return False
            self.flush_one(out)
        else:
            li, start_s = ns
            if start_s > horizon_s:
                return False
            self.dispatch_at(li, start_s, exec_fn)
        return True

    def run_until(self, horizon_s, exec_fn, out):
        while self.step(horizon_s, exec_fn, out):
            pass

    # ---- failure-injection surface (mirror of the rust dispatcher's
    # fault/timer API). Every method below is inert unless a harness
    # with a FaultSpec / retry policy drives it.

    def arm_timeout(self, rid, lane, deadline_s):
        """Arm a queue-wait deadline timer for a solo request."""
        self.timer_seq += 1
        self.armed[rid] = (self.timer_seq, lane)
        heapq.heappush(self.timers, (deadline_s, self.timer_seq, rid, lane))

    def next_timeout_s(self):
        """Earliest timer deadline, stale entries included (they pop as
        no-ops in fire_timeouts — lazy disarm, like the ghost purge)."""
        return self.timers[0][0] if self.timers else None

    def fire_timeouts(self, now_s):
        """Pop every timer due at or before now_s; pull each request
        that is genuinely still queued and return it for requeueing."""
        fired = []
        while self.timers and self.timers[0][0] <= now_s:
            _dl, seq, rid, li = heapq.heappop(self.timers)
            ent = self.armed.get(rid)
            if ent is None or ent[0] != seq or ent[1] != li:
                continue  # stale: dispatched or re-armed elsewhere
            del self.armed[rid]
            lane = self.lanes[li]
            for i, rq in enumerate(lane.items):
                if rq[0] == rid and rq[7] is None:
                    del lane.items[i]
                    lane.on_cancel(rq[4])
                    fired.append(rq)
                    break
        return fired

    def fail_lane(self, li, now_s):
        """Crash the lane: its queue and in-flight batches are lost
        (device memory is gone), admissions refuse until recovery.
        Returns (killed_requests, n_in_flight) in deterministic order:
        queue FIFO order first, then in-flight by dispatch seq. Hedged
        copies whose twin is still alive are not killed — the twin
        carries the request on."""
        lane = self.lanes[li]
        lane.down = True
        killed = []

        def kill_copy(rq):
            hid = rq[7]
            if hid is None:
                if self.armed is not None:
                    self.armed.pop(rq[0], None)
                killed.append(rq)
                return
            entry = self.arena[hid]
            side = 0 if entry[0] == li else 1
            if entry[4 + side] == CANCELLED:
                # Ghost awaiting lazy purge: result already delivered.
                self.arena_release(hid)
                return
            if entry[6] is not None:
                # Straggling loser of a decided race: close the entry.
                self.arena_release(hid)
                return
            if entry[4 + 1 - side] == CANCELLED:
                # Twin died in an earlier lane failure: request lost.
                self.arena_release(hid)
                killed.append(rq)
                return
            entry[4 + side] = CANCELLED  # twin carries the request on
        for rq in lane.items:
            kill_copy(rq)
        lane.items = []
        lane.dead = 0
        lane.backlog_est_s = 0.0
        dead_pending = sorted(
            (p for p in self.pending if p[4] == li), key=lambda p: p[1]
        )
        if dead_pending:
            self.pending = [p for p in self.pending if p[4] != li]
            heapq.heapify(self.pending)
        for p in dead_pending:
            kill_copy(p[5])
        for i in range(len(lane.free_at)):
            lane.free_at[i] = now_s
        return killed, len(dead_pending)

    def recover_lane(self, li, now_s):
        """Bring a crashed lane back: empty queue, idle workers."""
        lane = self.lanes[li]
        lane.down = False
        for i in range(len(lane.free_at)):
            lane.free_at[i] = max(lane.free_at[i], now_s)


# ---------------------------------------------------------------- fleet harness


class FleetState:
    """Mirror of run_fleet's selector + executor + accounting state,
    including the per-device refit banks (PlaneBank / LineBank), the
    waste-budget margin controller and lane-pinned drift."""

    def __init__(self, pool, topo, strategy, hedge_margin_s, pick_seed,
                 adaptive=None, drift=None, telemetry=None):
        self.pool = pool
        self.strategy = strategy
        self.hedge_margin_s = hedge_margin_s
        self.adaptive = adaptive
        self.drift = drift
        devs = topo["devices"]
        self.tiers = [d["tier"] for d in devs]
        self.slowdown = [1.0 / d["speed"] for d in devs]
        self.link_scale = [d["link_scale"] for d in devs]
        self.texe = []
        for d in devs:
            base = EDGE_PLANE if d["tier"] == EDGE else CLOUD_PLANE
            slow = 1.0 / d["speed"]
            self.texe.append((base[0] * slow, base[1] * slow, base[2] * slow))
        self.edge_ids = [i for i, t in enumerate(self.tiers) if t == EDGE]
        self.cloud_ids = [i for i, t in enumerate(self.tiers) if t == CLOUD]
        # Device health (mirror of fleet::DeviceHealth): None keeps the
        # selector health-blind (legacy behaviour, byte-identical); a
        # list of per-device states excludes non-Up devices (0) from
        # the placement arg-min.
        self.health = None
        self.ttx = TtxEstimator(TTX_ALPHA)
        # Per-device refit T_tx laws ((slope, intercept) once installed).
        self.ttx_lines = [None] * len(devs)
        self.disp = FleetDispatcher(self.tiers, [d["workers"] for d in devs])
        self.rr = [0, 0]
        self.pick_rng = Rng(pick_seed) if strategy == "random" else None
        # Per-device refit banks (mirror of FleetRefit: PlaneBank priors
        # are the selector's scaled planes; LineBank lines start diffuse
        # at zero, cloud devices only).
        if adaptive is not None:
            lam, pv = adaptive["rls_lambda"], adaptive["rls_prior_var"]
            self.planes = [Rls(self.texe[d], lam, pv) for d in range(len(devs))]
            self.lines = [
                Rls2(0.0, 0.0, lam, pv) if t == CLOUD else None for t in self.tiers
            ]
        else:
            self.planes = None
            self.lines = None
        # Waste-budget margin controller (FleetOpts::budget_ctl).
        if (
            adaptive is not None
            and strategy == "hedge"
            and hedge_margin_s > 0.0
            and adaptive.get("waste_budget", 0.0) > 0.0
        ):
            self.ctl = HedgeBudget(adaptive["waste_budget"], hedge_margin_s)
        else:
            self.ctl = None
        # Observability (mirror of obs::telemetry via FleetOpts.telemetry
        # — off by default so every legacy report stays byte-identical).
        if telemetry is not None:
            self.phases = Phases()
            self.tel = Telemetry(
                telemetry,
                [d["name"] for d in devs],
                adaptive is not None,
                self.ctl is not None,
            )
        else:
            self.phases = None
            self.tel = None
        # Accounting (mirror of FleetAcct).
        self.hist = Histogram()
        self.stats_count = 0
        self.stats_mean = 0.0
        self.device_results = [0] * len(devs)
        self.edge_count = 0
        self.cloud_count = 0
        self.completed = 0
        self.last_done_s = 0.0
        self.useful_work_s = 0.0
        self.wasted_work_s = 0.0

    def true_service_s(self, truth, li, start_s):
        """Mirror of fleet_true_service_s (slowdown, then lane-pinned
        drift)."""
        base = truth.t_edge if self.tiers[li] == EDGE else truth.t_cloud
        t = base * self.slowdown[li]
        if self.drift is not None and self.drift["lane"] == li:
            t *= fleet_drift_factor_at(self.drift, start_s)
        return t

    def exec_fn(self, li, batch, start_s):
        mx = 0.0
        sm = 0.0
        for rq in batch:
            t = self.true_service_s(self.pool[rq[1]], li, start_s)
            if t > mx:
                mx = t
            sm += t
        return mx + (sm - mx) * BATCH_RESIDUAL

    def best_of(self, ids, n, m_est, ttx_est, waits):
        best_d, best_score, best_est = -1, math.inf, math.inf
        for d in ids:
            if self.health is not None and self.health[d] != 0:
                continue  # Draining/Down: excluded from the arg-min
            est = texe_estimate(self.texe[d], n, m_est)
            if self.tiers[d] == EDGE:
                score = est + waits[d]
            else:
                line = self.ttx_lines[d]
                if line is not None:
                    net = max(line[0] * (n + m_est) + line[1], 0.0)
                else:
                    net = ttx_est * self.link_scale[d]
                score = net + est + waits[d]
            if score < best_score:
                best_d, best_score, best_est = d, score, est
        return best_d, best_score, best_est

    def select(self, n, waits):
        m_est = n2m_predict(N2M_GAMMA, N2M_DELTA, n)
        ttx_est = self.ttx.estimate_or(TTX_PRIOR)
        be = self.best_of(self.edge_ids, n, m_est, ttx_est, waits)
        bc = self.best_of(self.cloud_ids, n, m_est, ttx_est, waits)
        best = be if be[1] <= bc[1] else bc
        return {
            "device": best[0],
            "m_est": m_est,
            "est": best[2],
            "score": best[1],
            "best_edge": be,
            "best_cloud": bc,
        }

    def apply_refit(self):
        """Mirror of apply_fleet_refit: install every warmed per-device
        plane and per-link T_tx law."""
        if self.planes is None:
            return
        min_obs = self.adaptive["refit_min_obs"]
        for d in range(len(self.texe)):
            if self.planes[d].count >= min_obs:
                w = self.planes[d].w
                self.texe[d] = (w[0], w[1], w[2])
            line = self.lines[d]
            if (
                self.adaptive["refit_ttx"]
                and line is not None
                and line.count >= min_obs
            ):
                self.ttx_lines[d] = (line.w[0], line.w[1])

    def process(self, comps, on_result=None):
        for comp in comps:
            rq, li, start_s, done_s, _bsize, kind = comp
            truth = self.pool[rq[1]]
            tier = self.tiers[li]
            t_true = self.true_service_s(truth, li, start_s)
            is_result = kind != LOSS
            if kind == LOSS:
                self.wasted_work_s += t_true
                if self.ctl is not None:
                    self.ctl.observe(t_true, True)
            else:
                self.useful_work_s += t_true
                if self.ctl is not None:
                    self.ctl.observe(t_true, False)
                tx_s = truth.t_tx * self.link_scale[li] if tier == CLOUD else 0.0
                if self.phases is not None:
                    # The four phases partition the latency exactly:
                    # (start-arrival) + ((done-start)-t_true) + t_true + tx.
                    self.phases.record(
                        start_s - rq[5],
                        (done_s - start_s) - t_true,
                        t_true,
                        tx_s,
                    )
                latency = (done_s - rq[5]) + tx_s
                self.hist.record(latency)
                self.stats_count += 1
                self.stats_mean += (latency - self.stats_mean) / self.stats_count
                if tier == EDGE:
                    self.edge_count += 1
                else:
                    self.cloud_count += 1
                self.completed += 1
                if done_s + tx_s > self.last_done_s:
                    self.last_done_s = done_s + tx_s
            # Per-lane refit feedback — every observed execution counts,
            # wasted ones included (they are real measurements).
            if self.planes is not None:
                self.planes[li].observe(
                    float(truth.n), float(truth.m_real), t_true
                )
                if tier == CLOUD and self.adaptive["refit_ttx"]:
                    self.lines[li].observe(
                        float(truth.n + truth.m_real),
                        truth.t_tx * self.link_scale[li],
                    )
            if is_result:
                self.device_results[li] += 1
                if on_result is not None:
                    on_result(comp)


def fleet_submit(st, i, truth, now, n_dev, waits):
    """Mirror of fleet_route_and_submit: heartbeat, wait terms, arg-min
    placement (or blind override), budget-controlled hedging. Returns
    admitted."""
    if st.ttx.is_stale(now, TTX_REFRESH_S):
        st.ttx.observe(now, truth.rtt)
    queue_aware = st.strategy in ("select", "hedge")
    if queue_aware:
        for d in range(n_dev):
            waits[d] = st.disp.lanes[d].expected_wait_s(now)
    else:
        for d in range(n_dev):
            waits[d] = 0.0
    trace = st.select(truth.n, waits)
    bucket = int(max(trace["m_est"], 0.0) / BUCKET_WIDTH)
    rq = (i, i, truth.n, trace["m_est"], 0.0, now, bucket, None)
    hedge = False
    if st.strategy == "hedge":
        bar = st.ctl.margin_s if st.ctl is not None else st.hedge_margin_s
        margin = trace["best_edge"][1] - trace["best_cloud"][1]
        hedge = bar > 0.0 and math.isfinite(margin) and abs(margin) <= bar
    if hedge:
        be, bc = trace["best_edge"], trace["best_cloud"]
        outcome = st.disp.submit_hedged_lanes(rq, be[0], be[2], bc[0], bc[2])
        cloud_in_flight = outcome == "hedged" or (
            isinstance(outcome, tuple) and st.tiers[outcome[1]] == CLOUD
        )
        if cloud_in_flight:
            st.ttx.observe(now, truth.rtt)
        return outcome != "rejected"
    if st.strategy in ("select", "hedge"):
        dev = trace["device"]
    elif st.strategy == "static":
        ti = 0 if st.tiers[trace["device"]] == EDGE else 1
        ids = st.edge_ids if ti == 0 else st.cloud_ids
        dev = ids[st.rr[ti] % len(ids)]
        st.rr[ti] += 1
    else:  # random
        ids = st.edge_ids if st.tiers[trace["device"]] == EDGE else st.cloud_ids
        dev = ids[rng_usize(st.pick_rng, len(ids))]
    est = (
        trace["est"]
        if dev == trace["device"]
        else texe_estimate(st.texe[dev], truth.n, trace["m_est"])
    )
    rq = rq[:4] + (est,) + rq[5:]
    if st.tiers[dev] == CLOUD:
        st.ttx.observe(now, truth.rtt)
    return st.disp.submit_lane(dev, rq)


# ---------------------------------------------------------------- observability
# Mirror of rust/src/obs/telemetry.rs: the per-request latency phase
# decomposition and the fixed-cadence control-loop gauge sampler. Both
# only *read* the simulation state, so dynamics are bit-identical with
# telemetry on or off; both are off by default.


class Phases:
    """Mirror of obs::Phases: four latency-bucketed histograms that
    partition each result's latency exactly
    (queue_wait + batch_wait + exec + tx == latency)."""

    KEYS = ("queue_wait", "batch_wait", "exec", "tx")

    def __init__(self):
        self.hists = {k: Histogram() for k in self.KEYS}

    def record(self, queue_wait_s, batch_wait_s, exec_s, tx_s):
        self.hists["queue_wait"].record(queue_wait_s)
        self.hists["batch_wait"].record(batch_wait_s)
        self.hists["exec"].record(exec_s)
        self.hists["tx"].record(tx_s)

    @staticmethod
    def phase_json(h):
        return {
            "count": float(h.total),
            "mean_s": h.sum / h.total if h.total else float("nan"),
            "p50_s": h.quantile(0.50),
            "p95_s": h.quantile(0.95),
            "p99_s": h.quantile(0.99),
            "sum_s": h.sum,
        }

    def to_json(self):
        return {k: self.phase_json(h) for k, h in self.hists.items()}


class Telemetry:
    """Mirror of obs::Telemetry: a fixed-cadence, fixed-capacity sampler
    of per-device gauges plus the adaptive-control state. The first
    sample lands at `interval_s`; a due sample with the window full
    flags `truncated` instead of rotating."""

    def __init__(self, cfg, names, adaptive, controlled):
        self.interval_s = cfg["interval_s"]
        self.capacity = max(cfg["capacity"], 1)
        self.next_s = cfg["interval_s"]
        self.t_s = []
        self.devices = [
            {
                "name": n,
                "queue_depth": [],
                "expected_wait_s": [],
                "in_flight": [],
                "plane": [[], [], []] if adaptive else None,
            }
            for n in names
        ]
        self.hedge_margin_s = [] if controlled else None
        self.wasted_frac = [] if controlled else None
        self.truncated = False

    def next_due(self, now_s):
        if self.next_s > now_s:
            return None
        if len(self.t_s) >= self.capacity:
            self.truncated = True
            return None
        t = self.next_s
        self.next_s += self.interval_s
        self.t_s.append(t)
        return t

    def to_json(self):
        out = {
            "interval_s": self.interval_s,
            "samples": float(len(self.t_s)),
            "truncated": self.truncated,
            "t_s": list(self.t_s),
            "devices": [],
        }
        for dev in self.devices:
            o = {
                "name": dev["name"],
                "queue_depth": list(dev["queue_depth"]),
                "expected_wait_s": list(dev["expected_wait_s"]),
                "in_flight": list(dev["in_flight"]),
            }
            if dev["plane"] is not None:
                o["plane_an"] = list(dev["plane"][0])
                o["plane_am"] = list(dev["plane"][1])
                o["plane_b"] = list(dev["plane"][2])
            out["devices"].append(o)
        if self.hedge_margin_s is not None:
            out["hedge_margin_s"] = list(self.hedge_margin_s)
        if self.wasted_frac is not None:
            out["wasted_frac"] = list(self.wasted_frac)
        return out


def sample_telemetry(st, now_s):
    """Mirror of harness::sample_telemetry: claim every cadence point due
    at or before `now_s` and sample the gauges at the claimed instant."""
    tel = st.tel
    if tel is None:
        return
    while True:
        ts = tel.next_due(now_s)
        if ts is None:
            break
        for d, dev in enumerate(tel.devices):
            lane = st.disp.lanes[d]
            dev["queue_depth"].append(float(len(lane.items) - lane.dead))
            dev["expected_wait_s"].append(lane.expected_wait_s(ts))
            dev["in_flight"].append(
                float(sum(1 for t in lane.free_at if t > ts))
            )
            if dev["plane"] is not None:
                an, am, b = st.texe[d]
                dev["plane"][0].append(an)
                dev["plane"][1].append(am)
                dev["plane"][2].append(b)
        if st.ctl is not None:
            if tel.hedge_margin_s is not None:
                tel.hedge_margin_s.append(st.ctl.margin_s)
            if tel.wasted_frac is not None:
                total = st.ctl.useful_s + st.ctl.wasted_s
                tel.wasted_frac.append(
                    st.ctl.wasted_s / total if total > 0.0 else 0.0
                )


def fleet_label(strategy, adaptive):
    label = {
        "static": "fleet+static",
        "random": "fleet+random",
        "select": "fleet+select",
        "hedge": "fleet+hedge",
    }[strategy]
    return label + "+refit" if adaptive is not None else label


def finish_fleet(st, offered, rejected, makespan_s):
    disp = st.disp
    useful = st.useful_work_s
    wasted = st.wasted_work_s
    total_work = useful + wasted
    queue_aware = st.strategy in ("select", "hedge")
    out = {
        "policy": fleet_label(st.strategy, st.adaptive),
        "queue_aware": queue_aware,
        "offered": float(offered),
        "completed": float(st.completed),
        "rejected": float(rejected),
        "shed_rate": (rejected / offered) if offered else 0.0,
        "edge_count": float(st.edge_count),
        "cloud_count": float(st.cloud_count),
        "makespan_s": makespan_s,
        "throughput_rps": st.completed / makespan_s if makespan_s > 0.0 else 0.0,
        "mean_latency_s": st.stats_mean if st.stats_count else float("nan"),
        "p50_s": st.hist.quantile(0.50),
        "p95_s": st.hist.quantile(0.95),
        "p99_s": st.hist.quantile(0.99),
        "mean_batch": (
            disp.batch_requests / disp.batches if disp.batches else float("nan")
        ),
        "hedged": float(disp.hs_hedged),
        "hedge_rate": (disp.hs_hedged / offered) if offered else 0.0,
        "hedge_wins_edge": float(disp.hs_wins_edge),
        "hedge_wins_cloud": float(disp.hs_wins_cloud),
        "hedge_cancelled": float(disp.hs_cancelled),
        "hedge_wasted": float(disp.hs_losers),
        "useful_work_s": useful,
        "wasted_work_s": wasted,
        "wasted_frac": wasted / total_work if total_work > 0.0 else 0.0,
        "device_results": [float(c) for c in st.device_results],
        "peak_depths": [float(lane.peak_depth) for lane in disp.lanes],
    }
    if st.ctl is not None:
        out["hedge_final_margin_s"] = st.ctl.margin_s
    # Observability blocks — telemetry runs only (legacy layout
    # untouched otherwise).
    if st.phases is not None:
        out["phases"] = st.phases.to_json()
    if st.tel is not None:
        out["telemetry"] = st.tel.to_json()
    return out


def run_fleet(pool, topo, strategy, hedge_margin_s=FLEET_HEDGE_MARGIN_S, pick_seed=0,
              adaptive=None, drift=None, telemetry=None):
    st = FleetState(pool, topo, strategy, hedge_margin_s, pick_seed, adaptive,
                    drift, telemetry)
    n_dev = len(st.tiers)
    waits = [0.0] * n_dev
    rejected = 0
    for i, truth in enumerate(pool):
        now = truth.arrival_s
        # Gauges read the pre-arrival dispatcher state.
        sample_telemetry(st, now)
        comps = []
        st.disp.run_until(now, st.exec_fn, comps)
        st.process(comps)
        if adaptive is not None:
            st.apply_refit()
        if not fleet_submit(st, i, truth, now, n_dev, waits):
            rejected += 1
    comps = []
    st.disp.run_until(float("inf"), st.exec_fn, comps)
    st.process(comps)
    sample_telemetry(st, st.last_done_s)

    first_arrival = pool[0].arrival_s if pool else 0.0
    makespan_s = max(st.last_done_s - first_arrival, 0.0)
    return finish_fleet(st, len(pool), rejected, makespan_s)


def run_fleet_closed(pool, topo, strategy, clients, think_s=0.0,
                     hedge_margin_s=FLEET_HEDGE_MARGIN_S, pick_seed=0,
                     adaptive=None, drift=None, telemetry=None):
    """Mirror of sim::harness::run_fleet_closed (bounded-outstanding
    clients driving the N-lane fleet path)."""
    total = len(pool)
    st = FleetState(pool, topo, strategy, hedge_margin_s, pick_seed, adaptive,
                    drift, telemetry)
    n_dev = len(st.tiers)
    waits = [0.0] * n_dev
    ready_s = [0.0] * clients
    waiting = [False] * clients
    client_of = [0] * total
    next_body = 0
    rejected = 0
    resolved = [0]

    while resolved[0] < total:
        t_submit = float("inf")
        client = -1
        if next_body < total:
            for k in range(clients):
                if not waiting[k] and ready_s[k] < t_submit:
                    t_submit = ready_s[k]
                    client = k
        next_event = st.disp.next_event_s()
        submit_first = client != -1 and (next_event is None or t_submit <= next_event)
        # The next action's instant — a submission or the dispatcher
        # event — drives the telemetry clock (gauges read the pre-action
        # dispatcher state).
        if submit_first:
            t_act = t_submit
        else:
            if next_event is None:
                break
            t_act = next_event
        sample_telemetry(st, t_act)
        if submit_first:
            body = next_body
            next_body += 1
            client_of[body] = client
            if fleet_submit(st, body, pool[body], t_submit, n_dev, waits):
                waiting[client] = True
            else:
                rejected += 1
                resolved[0] += 1
        else:
            comps = []
            st.disp.step(t_act, st.exec_fn, comps)

            def on_result(comp):
                rq, li, _start_s, done_s, _bsize, _kind = comp
                k = client_of[rq[1]]
                tx_s = (
                    pool[rq[1]].t_tx * st.link_scale[li]
                    if st.tiers[li] == CLOUD
                    else 0.0
                )
                waiting[k] = False
                ready_s[k] = done_s + tx_s + think_s
                resolved[0] += 1

            st.process(comps, on_result)
            if adaptive is not None:
                st.apply_refit()
    comps = []
    st.disp.run_until(float("inf"), st.exec_fn, comps)
    st.process(comps)
    sample_telemetry(st, st.last_done_s)
    makespan_s = max(st.last_done_s, 0.0)
    return finish_fleet(st, total, rejected, makespan_s)


# ---------------------------------------------------------------- 1x1 anchor check


def check_pair_anchor(requests=4000, load=96.0):
    """Re-prove the 1×1 differential on every run: the fleet path on the
    pair topology must reproduce the pair mirror float-for-float — now
    including the per-device refit banks, the waste-budget hedge
    controller and the closed-loop client loop."""
    pool = synth_workload(0xF1EE7 + int(load), requests, load)
    topo = topo_pair()
    fields = [
        "offered",
        "completed",
        "rejected",
        "edge_count",
        "cloud_count",
        "makespan_s",
        "throughput_rps",
        "mean_latency_s",
        "p50_s",
        "p95_s",
        "p99_s",
        "mean_batch",
        "hedged",
        "hedge_wins_edge",
        "hedge_wins_cloud",
        "hedge_cancelled",
        "hedge_wasted",
        "useful_work_s",
        "wasted_work_s",
    ]

    def compare(tag, fleet_r, pair_r):
        for f in fields:
            fv, pv = fleet_r[f], pair_r[f]
            same = (fv == pv) or (math.isnan(fv) and math.isnan(pv))
            assert same, f"1x1 anchor diverged [{tag}] {f}: fleet {fv} vs pair {pv}"
        assert fleet_r["peak_depths"] == [
            pair_r["edge_peak_depth"],
            pair_r["cloud_peak_depth"],
        ], f"1x1 anchor diverged [{tag}] peak depths"
        fm = fleet_r.get("hedge_final_margin_s")
        pm = pair_r.get("hedge_final_margin_s")
        assert fm == pm, f"1x1 anchor diverged [{tag}] final margin: {fm} vs {pm}"

    compare("static≡cnmt", run_fleet(pool, topo, "static"), run_contended(pool, "cnmt", False))
    compare(
        "random≡cnmt",
        run_fleet(pool, topo, "random", pick_seed=7),
        run_contended(pool, "cnmt", False),
    )
    compare(
        "select≡cnmt+queue",
        run_fleet(pool, topo, "select"),
        run_contended(pool, "cnmt", True),
    )
    no_refit = {
        "hedge_margin_s": FLEET_HEDGE_MARGIN_S,
        "rls_lambda": 0.998,
        "rls_prior_var": 1.0,
        "refit_min_obs": float("inf"),  # the refit planes never install
        "refit_ttx": False,
        "waste_budget": 0.0,  # fixed margin, like the adaptive-less fleet side
    }
    compare(
        "hedge≡cnmt+adaptive[no-refit]",
        run_fleet(pool, topo, "hedge"),
        run_contended(pool, "cnmt", True, no_refit),
    )
    # Per-device refit enabled on both sides (hedging off): the
    # PlaneBank/LineBank arithmetic must match the pair's two planes +
    # one line exactly.
    refit_only = dict(ADAPTIVE_DEFAULTS, hedge_margin_s=0.0)
    compare(
        "select+refit≡cnmt+adaptive[no-hedge]",
        run_fleet(pool, topo, "select", adaptive=refit_only),
        run_contended(pool, "cnmt", True, refit_only),
    )
    # Full adaptive stack: refit + budget-controlled hedging, plus a
    # lane-pinned drift on device 0 ≡ the pair's edge-tier drift.
    drift_fleet = {"lane": 0, "start_s": 14.0, "ramp_s": 10.0, "factor": 2.5}
    drift_pair = (0, 14.0, 10.0, 2.5)  # (EDGE, start, ramp, factor)
    compare(
        "hedge+refit+budget≡cnmt+adaptive",
        run_fleet(pool, topo, "hedge", adaptive=ADAPTIVE_DEFAULTS),
        run_contended(pool, "cnmt", True, ADAPTIVE_DEFAULTS),
    )
    compare(
        "hedge+refit+budget+drift≡cnmt+adaptive+drift",
        run_fleet(pool, topo, "hedge", adaptive=ADAPTIVE_DEFAULTS, drift=drift_fleet),
        run_contended(pool, "cnmt", True, ADAPTIVE_DEFAULTS, drift_pair),
    )
    # Closed-loop leg: run_fleet_closed ≡ run_closed_loop.
    closed_pool = pool[: min(len(pool), 2000)]
    compare(
        "closed select≡cnmt+queue",
        run_fleet_closed(closed_pool, topo, "select", 8),
        run_closed_loop(closed_pool, "cnmt", True, None, 8, 0.0),
    )
    compare(
        "closed hedge+refit+budget≡cnmt+adaptive",
        run_fleet_closed(closed_pool, topo, "hedge", 8, adaptive=ADAPTIVE_DEFAULTS),
        run_closed_loop(closed_pool, "cnmt", True, ADAPTIVE_DEFAULTS, 8, 0.0),
    )
    print(
        f"1x1 anchor OK: fleet path ≡ pair path over {requests} requests @ "
        f"{load:g} r/s (incl. refit, waste budget, drift, closed loop)"
    )


# ---------------------------------------------------------------- sweep + json

STRATEGIES = ["static", "random", "select", "hedge"]


def topo_to_json(topo):
    """Mirror of Topology::to_json."""
    return {
        "name": topo["name"],
        "devices": [
            {
                "name": d["name"],
                "tier": d["tier"],
                "speed": d["speed"],
                "workers": float(d["workers"]),
                "link_scale": d["link_scale"],
            }
            for d in topo["devices"]
        ],
    }


def run_sweep(shape_names, requests_per_point, seed=SEED):
    cells = []
    for i, name in enumerate(shape_names):
        topo = topo_preset(name)
        offered = OFFERED_RPS.get(name)
        if offered is None:
            edges = sum(1 for d in topo["devices"] if d["tier"] == EDGE)
            clouds = len(topo["devices"]) - edges
            offered = edges * 16.0 + clouds * 112.0
        workload_seed = cell_seed(seed, i)
        pool = synth_workload(workload_seed, requests_per_point, offered)
        policies = {}
        for strategy in STRATEGIES:
            r = run_fleet(
                pool,
                topo,
                strategy,
                FLEET_HEDGE_MARGIN_S,
                pick_seed=workload_seed ^ RANDOM_PICK_TAG,
            )
            policies[r["policy"]] = r
        cells.append(
            {"name": topo["name"], "topo": topo, "offered_rps": offered, "policies": policies}
        )
    return cells


def sweep_to_json(cells, requests_per_point, seed=SEED):
    shapes = []
    headline = float("nan")
    # First 8x4 cell, else the last cell — mirror of FleetSweep::headline_cell.
    headline_cell = next((c for c in cells if c["name"] == "8x4"), None)
    if headline_cell is None and cells:
        headline_cell = cells[-1]
    for c in cells:
        edges = sum(1 for d in c["topo"]["devices"] if d["tier"] == EDGE)
        clouds = len(c["topo"]["devices"]) - edges
        vs_random = c["policies"]["fleet+random"]["p99_s"] / c["policies"]["fleet+select"]["p99_s"]
        vs_static = c["policies"]["fleet+static"]["p99_s"] / c["policies"]["fleet+select"]["p99_s"]
        if c is headline_cell:
            headline = vs_random
        shapes.append(
            {
                "name": c["name"],
                "offered_rps": c["offered_rps"],
                "edges": float(edges),
                "clouds": float(clouds),
                "topology": topo_to_json(c["topo"]),
                "policies": c["policies"],
                "p99_ratio_vs_random": vs_random,
                "p99_ratio_vs_static": vs_static,
            }
        )
    return {
        "seed": float(SEED if seed is None else seed),
        "requests_per_point": float(requests_per_point),
        "hedge_margin_s": FLEET_HEDGE_MARGIN_S,
        "shapes": shapes,
        "headline_p99_ratio": headline,
    }


# ---------------------------------------------------------------- closed-loop sweep

# (strategy, adaptive) per configuration — mirror of
# experiments::fleet::closed_configurations.
CLOSED_CONFIGS = [
    ("static", None),
    ("select", None),
    ("select", ADAPTIVE_DEFAULTS),
    ("hedge", ADAPTIVE_DEFAULTS),
]


def closed_drift_spec(topo, requests_per_point):
    """Mirror of experiments::fleet::closed_drift_spec: pin the lead
    edge gateway, start at a quarter of the nominal run."""
    lane = next(i for i, d in enumerate(topo["devices"]) if d["tier"] == EDGE)
    offered = OFFERED_RPS.get(topo["name"])
    if offered is None:
        edges = sum(1 for d in topo["devices"] if d["tier"] == EDGE)
        clouds = len(topo["devices"]) - edges
        offered = edges * 16.0 + clouds * 112.0
    return {
        "device": "edge",
        "lane": lane,
        "start_s": (requests_per_point / offered) * FLEET_CLOSED_DRIFT_START_FRAC,
        "ramp_s": FLEET_CLOSED_DRIFT_RAMP_S,
        "factor": FLEET_CLOSED_DRIFT_FACTOR,
    }


def run_closed_sweep(clients_list, requests_per_point, think_s=0.0, seed=SEED,
                     telemetry=None):
    topo = topo_hetero()
    drift = closed_drift_spec(topo, requests_per_point)
    pool = synth_workload(seed ^ FLEET_CLOSED_SEED_TAG, requests_per_point, 1.0)
    cells = []
    for clients in clients_list:
        policies = {}
        for strategy, adaptive in CLOSED_CONFIGS:
            r = run_fleet_closed(
                pool,
                topo,
                strategy,
                clients,
                think_s,
                FLEET_HEDGE_MARGIN_S,
                0,
                adaptive,
                drift,
                telemetry,
            )
            policies[r["policy"]] = r
        cells.append({"clients": clients, "policies": policies})
    return topo, drift, cells


def closed_sweep_to_json(topo, drift, cells, requests_per_point, think_s, seed=SEED):
    points = []
    for c in cells:
        ratio = (
            c["policies"]["fleet+select"]["p99_s"]
            / c["policies"]["fleet+select+refit"]["p99_s"]
        )
        points.append(
            {
                "clients": float(c["clients"]),
                "policies": c["policies"],
                "p99_ratio_vs_baseline": ratio,
            }
        )
    headline = points[-1]["p99_ratio_vs_baseline"] if points else float("nan")
    max_waste = 0.0
    for c in cells:
        max_waste = max(max_waste, c["policies"]["fleet+hedge+refit"]["wasted_frac"])
    return {
        "seed": float(seed),
        "requests_per_point": float(requests_per_point),
        "think_s": think_s,
        "topology": topo_to_json(topo),
        "drift": {
            "device": drift["device"],
            "factor": drift["factor"],
            "lane": float(drift["lane"]),
            "ramp_s": drift["ramp_s"],
            "start_s": drift["start_s"],
        },
        "hedge_margin_s": FLEET_HEDGE_MARGIN_S,
        "waste_budget": ADAPTIVE_DEFAULTS["waste_budget"],
        "points": points,
        "headline_p99_ratio": headline,
        "max_hedge_wasted_frac": max_waste,
    }


def summarize_closed(topo, drift, cells):
    hdr = (
        f"{'K':>4} {'policy':<19} {'goodput':>8} {'mean ms':>8} {'p50ms':>8} "
        f"{'p95ms':>8} {'p99ms':>9} {'batch':>6} {'hedge%':>7} {'waste%':>7} "
        f"{'edge/cloud':>12}"
    )
    print(hdr)
    print("-" * len(hdr))
    for c in cells:
        for strategy, adaptive in CLOSED_CONFIGS:
            label = fleet_label(strategy, adaptive)
            r = c["policies"][label]
            print(
                f"{c['clients']:>4} {label:<19} {r['throughput_rps']:>8.1f} "
                f"{r['mean_latency_s'] * 1e3:>8.1f} {r['p50_s'] * 1e3:>8.1f} "
                f"{r['p95_s'] * 1e3:>8.1f} {r['p99_s'] * 1e3:>9.1f} "
                f"{r['mean_batch']:>6.2f} {r['hedge_rate'] * 100:>7.1f} "
                f"{r['wasted_frac'] * 100:>7.1f} "
                f"{int(r['edge_count'])}/{int(r['cloud_count']):>5}"
            )
    name = topo["devices"][drift["lane"]]["name"]
    print(
        f"\ndrift: {name} (device {drift['lane']}) slows {drift['factor']:.1f}x "
        f"from t={drift['start_s']:.0f}s (ramp {drift['ramp_s']:.0f}s)"
    )
    for c in cells:
        sel = c["policies"]["fleet+select"]["p99_s"]
        refit = c["policies"]["fleet+select+refit"]["p99_s"]
        print(
            f"K={c['clients']}: per-device refit p99 {sel / refit:.1f}x shorter "
            f"than the tier-baseline selector"
        )


def summarize(cells):
    hdr = (
        f"{'shape':>7} {'policy':<13} {'goodput':>8} {'shed%':>6} {'p50ms':>8} "
        f"{'p95ms':>8} {'p99ms':>9} {'batch':>6} {'hedge%':>7} {'waste%':>7} {'edge/cloud':>12}"
    )
    print(hdr)
    print("-" * len(hdr))
    for c in cells:
        for label in ["fleet+static", "fleet+random", "fleet+select", "fleet+hedge"]:
            r = c["policies"][label]
            print(
                f"{c['name']:>7} {label:<13} {r['throughput_rps']:>8.1f} "
                f"{r['shed_rate'] * 100:>6.1f} {r['p50_s'] * 1e3:>8.1f} "
                f"{r['p95_s'] * 1e3:>8.1f} {r['p99_s'] * 1e3:>9.1f} "
                f"{r['mean_batch']:>6.2f} {r['hedge_rate'] * 100:>7.1f} "
                f"{r['wasted_frac'] * 100:>7.1f} "
                f"{int(r['edge_count'])}/{int(r['cloud_count']):>5}"
            )
    for c in cells:
        sel = c["policies"]["fleet+select"]["p99_s"]
        rnd = c["policies"]["fleet+random"]["p99_s"]
        sta = c["policies"]["fleet+static"]["p99_s"]
        print(
            f"{c['name']} @ {c['offered_rps']:g} r/s: select p99 {rnd / sel:.1f}x "
            f"shorter than random, {sta / sel:.1f}x shorter than static"
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--shapes",
        default=None,
        help="comma-separated presets (mirrors cnmt --shapes; default 1x1,4x2,8x4,hetero)",
    )
    ap.add_argument(
        "--requests",
        type=int,
        default=REQUESTS_PER_POINT,
        help="requests per (shape x strategy) cell (mirrors cnmt --fleet-requests)",
    )
    ap.add_argument(
        "--closed-loop",
        action="store_true",
        help="the closed-loop drift sweep on the hetero topology "
        "(mirrors cnmt experiment fleet --closed-loop; writes "
        "fleet_closed_loop.json)",
    )
    ap.add_argument(
        "--clients",
        default=None,
        help="closed loop: comma-separated client counts (default 8,16,32,64)",
    )
    ap.add_argument(
        "--think-ms",
        type=float,
        default=0.0,
        help="closed loop: per-client think time in ms (mirrors cnmt --think-ms)",
    )
    ap.add_argument(
        "--anchor-requests",
        type=int,
        default=4000,
        help="request count of the always-on 1x1 pair-equivalence check (0 skips)",
    )
    args = ap.parse_args()

    if args.anchor_requests > 0:
        check_pair_anchor(args.anchor_requests)

    if args.closed_loop:
        clients = (
            [int(s) for s in args.clients.split(",")]
            if args.clients
            else FLEET_CLOSED_CLIENTS
        )
        think_s = args.think_ms / 1e3
        topo, drift, cells = run_closed_sweep(clients, args.requests, think_s)
        root = closed_sweep_to_json(topo, drift, cells, args.requests, think_s)
        write_json(args.out or "reports/fleet_closed_loop.json", root)
        summarize_closed(topo, drift, cells)
        print(
            "\nheadline: per-device refit vs tier-baseline p99 at max K = "
            f"{root['headline_p99_ratio']:.1f}x; hedge waste peaks at "
            f"{root['max_hedge_wasted_frac'] * 100:.1f}% against a "
            f"{root['waste_budget'] * 100:.0f}% budget"
        )
        return

    shape_names = args.shapes.split(",") if args.shapes else DEFAULT_SHAPES
    cells = run_sweep([s.strip() for s in shape_names], args.requests)
    root = sweep_to_json(cells, args.requests)
    write_json(args.out or "reports/fleet_sweep.json", root)
    summarize(cells)
    print(
        "\nheadline: select vs random p99 on the headline shape = "
        f"{root['headline_p99_ratio']:.1f}x"
    )


if __name__ == "__main__":
    main()
