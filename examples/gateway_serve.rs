//! End-to-end serving driver (DESIGN.md §9.5): a real gateway serving a
//! batch of translation requests through the full stack —
//!
//!   corpus request stream → C-NMT router (eq. 1/2) → edge/cloud device
//!   actors, each executing the real AOT artifacts via PJRT → latency /
//!   throughput report.
//!
//! The edge/cloud physics of the paper's testbed are emulated with an
//! `edge_slowdown` stretch and a replayed RTT trace (DESIGN.md §4); the
//! router is characterised from *measured* runs at startup, exactly like
//! `cnmt calibrate`.
//!
//! ```sh
//! make artifacts
//! cargo run --release --offline --example gateway_serve -- \
//!     [--model gru_fr_en] [--requests 60] [--edge-slowdown 4] [--rtt-ms 12]
//! ```

use std::path::PathBuf;

use cnmt::coordinator::gateway::{Gateway, GatewayConfig};
use cnmt::coordinator::{PolicyKind, RouterBuilder};
use cnmt::corpus::{CorpusGenerator, LangPair, PrefilterRules};
use cnmt::devices::DeviceKind;
use cnmt::net::RttTrace;
use cnmt::predictor::{N2mRegressor, TexeModel};
use cnmt::runtime::{Seq2SeqEngine, TranslateOptions};
use cnmt::util::{Args, Rng};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let model = args.str("model", "gru_fr_en");
    let n_requests = args.usize("requests", 60)?;
    let edge_slowdown = args.f64("edge-slowdown", 4.0)?;
    // Default RTT chosen so the decision boundary falls inside the corpus
    // length range given the x4 edge handicap (edge wins short requests,
    // cloud wins long ones); lower it and everything offloads.
    let rtt_ms = args.f64("rtt-ms", 35.0)?;
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    args.reject_unknown()?;

    let pair = LangPair::from_id(
        model.trim_start_matches(|c: char| c.is_alphanumeric() == false)
            .splitn(2, '_')
            .nth(1)
            .unwrap_or("fr_en"),
    )
    .unwrap_or(LangPair::FrEn);

    // ---- offline phase: measured characterisation (mini `calibrate`) --
    eprintln!("[1/3] measuring T_exe on the local runtime ({model})...");
    let engine = Seq2SeqEngine::load(&artifacts, &model)?;
    let mut rng = Rng::new(42);
    let mut samples = Vec::new();
    for _ in 0..2 {
        let warm_opts = TranslateOptions { force_steps: Some(4), ..Default::default() };
        engine.translate(&[7u16; 8], warm_opts)?;
    }
    for _ in 0..24 {
        let n = 2 + rng.usize(58);
        let m = 2 + rng.usize(58);
        let src: Vec<u16> = (0..n).map(|_| 3 + rng.usize(4093) as u16).collect();
        let tr = engine.translate(
            &src,
            TranslateOptions { force_steps: Some(m), ..Default::default() },
        )?;
        samples.push((n as f64, m as f64, tr.total_s()));
    }
    drop(engine); // the gateway actors load their own engines
    let base = TexeModel::fit(&samples)?;
    let texe_edge = TexeModel::from_coeffs(
        base.alpha_n * edge_slowdown,
        base.alpha_m * edge_slowdown,
        base.beta * edge_slowdown,
    );
    eprintln!(
        "    edge plane: aN={:.3}ms aM={:.3}ms b={:.3}ms (r2 {:.3})",
        texe_edge.alpha_n * 1e3,
        texe_edge.alpha_m * 1e3,
        texe_edge.beta * 1e3,
        base.r2
    );

    // N→M regressor from the language pair's (synthetic) corpus.
    let mut gen = CorpusGenerator::new(pair, 7);
    let fit_pairs = gen.take(5_000);
    let n2m = N2mRegressor::fit(&fit_pairs, &PrefilterRules::default())?;
    eprintln!(
        "    n2m: gamma={:.3} delta={:.3} (r2 {:.3})",
        n2m.gamma, n2m.delta, n2m.r2
    );

    // ---- gateway -------------------------------------------------------
    eprintln!("[2/3] starting gateway (edge x{edge_slowdown}, rtt {rtt_ms} ms)...");
    let router = RouterBuilder::new(PolicyKind::Cnmt)
        .texe(texe_edge, base)
        .n2m(n2m)
        .ttx(0.3, rtt_ms / 1e3)
        .build()?;
    let trace = RttTrace {
        t: vec![0.0, 1e6],
        rtt: vec![rtt_ms / 1e3, rtt_ms / 1e3],
    };
    let gw = Gateway::start(
        GatewayConfig {
            artifacts_dir: artifacts,
            model: model.clone(),
            edge_slowdown,
            trace: Some(trace),
            max_steps: Some(48),
        },
        router,
    )?;

    // ---- request stream -------------------------------------------------
    eprintln!("[3/3] serving {n_requests} requests...");
    let mut stream_gen = CorpusGenerator::new(pair, 99);
    let t0 = std::time::Instant::now();
    let (mut edge_n, mut cloud_n) = (0usize, 0usize);
    for i in 0..n_requests {
        let p = stream_gen.next_pair();
        let out = gw.submit(i as u64, &p.src, Some(p.m_real.min(48)))?;
        match out.device {
            DeviceKind::Edge => edge_n += 1,
            DeviceKind::Cloud => cloud_n += 1,
        }
        if i < 5 || i + 1 == n_requests {
            println!(
                "req {i:>4}: n={:<2} m={:<2} -> {:<5}  exec {:>7.2} ms  tx {:>6.2} ms  total {:>7.2} ms",
                p.src.len(),
                out.steps,
                out.device.id(),
                out.exec_s * 1e3,
                out.tx_s * 1e3,
                out.latency_s * 1e3
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== gateway report ===");
    println!(
        "requests: {n_requests} ({edge_n} edge / {cloud_n} cloud), wall {:.2} s, throughput {:.1} req/s",
        wall,
        n_requests as f64 / wall
    );
    println!("{}", gw.metrics().to_string_pretty());
    assert!(edge_n > 0 && cloud_n > 0, "expected mixed routing in this setup");
    println!("OK: mixed edge/cloud routing verified");
    Ok(())
}
