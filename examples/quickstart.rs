//! Quickstart: load an AOT-compiled NMT model and translate a sentence —
//! the smallest possible use of the public API.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use cnmt::corpus::Tokenizer;
use cnmt::runtime::{Seq2SeqEngine, TranslateOptions};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Load a model (HLO text + weights, compiled via PJRT).
    let engine = Seq2SeqEngine::load(std::path::Path::new("artifacts"), "gru_fr_en")?;
    println!(
        "loaded {} ({:.1} MB of weights) on the CPU PJRT backend",
        engine.model_name(),
        engine.weights_bytes() as f64 / 1e6
    );

    // 2. Tokenize a (pseudo-word) sentence.
    let tok = Tokenizer::new(4096);
    let text = "bado gani pelu bima nade";
    let src = tok.tokenize(text)?;
    println!("source: {text}  ->  ids {src:?}");

    // 3. Translate: one encoder pass + greedy autoregressive decoding.
    let tr = engine.translate(
        &src,
        TranslateOptions { max_steps: Some(16), ..Default::default() },
    )?;
    let out: Vec<u16> = tr.tokens.iter().map(|&t| t as u16).collect();
    println!("output: {}", tok.detokenize(&out));
    println!(
        "latency: encode {:.2} ms + decode {:.2} ms ({} steps, {:.2} ms/token)",
        tr.encode_s * 1e3,
        tr.decode_s * 1e3,
        tr.steps,
        tr.decode_s * 1e3 / tr.steps.max(1) as f64
    );
    Ok(())
}
