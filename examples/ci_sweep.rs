//! Decision-boundary sweep — an ASCII rendering of the paper's Fig. 2b:
//! for each (input length N, network RTT) cell, which device does C-NMT
//! pick? Shows the Edge Region / Cloud Region split and how it moves
//! with connection quality, per model.
//!
//! ```sh
//! cargo run --release --offline --example ci_sweep -- [--pair en_zh]
//! ```

use cnmt::coordinator::{PolicyKind, RouterBuilder};
use cnmt::corpus::LangPair;
use cnmt::devices::{Calibration, DeviceKind};
use cnmt::predictor::N2mRegressor;
use cnmt::util::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let pair_id = args.str("pair", "");
    args.reject_unknown()?;

    let cal = Calibration::default_paper();
    let pairs: Vec<LangPair> = if pair_id.is_empty() {
        LangPair::ALL.to_vec()
    } else {
        vec![LangPair::from_id(&pair_id).ok_or("unknown pair")?]
    };

    for pair in pairs {
        let model = pair.model_name();
        let texe_e = cal.get(DeviceKind::Edge, model)?.texe;
        let texe_c = cal.get(DeviceKind::Cloud, model)?.texe;
        let p = pair.params();
        let n2m = N2mRegressor::from_coeffs(p.gamma, p.delta);

        println!("\n=== {} ({}) — '.' = edge, '#' = cloud ===", pair.id(), model);
        println!("gamma={:.2}: M ~ {:.2}N{:+.2}", p.gamma, p.gamma, p.delta);
        print!("{:>8} |", "RTT\\N");
        for n in (2..=62).step_by(4) {
            print!("{n:>3}");
        }
        println!();
        println!("{}", "-".repeat(8 + 2 + 16 * 3));
        for rtt_ms in [0, 10, 20, 40, 60, 80, 120, 160, 240, 320] {
            let mut router = RouterBuilder::new(PolicyKind::Cnmt)
                .texe(texe_e, texe_c)
                .n2m(n2m)
                .ttx(1.0, rtt_ms as f64 / 1e3)
                .build()?;
            router.observe_ttx(0.0, rtt_ms as f64 / 1e3);
            print!("{rtt_ms:>5} ms |");
            for n in (2..=62).step_by(4) {
                let d = router.decide(n);
                print!(
                    "{:>3}",
                    if d.device == DeviceKind::Edge { "." } else { "#" }
                );
            }
            println!();
        }
        // Find the crossover at two reference RTTs (the CP means).
        for rtt_ms in [95.0, 45.0] {
            let mut router = RouterBuilder::new(PolicyKind::Cnmt)
                .texe(texe_e, texe_c)
                .n2m(n2m)
                .ttx(1.0, rtt_ms / 1e3)
                .build()?;
            router.observe_ttx(0.0, rtt_ms / 1e3);
            let crossover = (1..=62).find(|&n| {
                router.decide(n).device == DeviceKind::Cloud
            });
            match crossover {
                Some(n) => println!(
                    "at {rtt_ms:.0} ms RTT: cloud region starts at N = {n}"
                ),
                None => println!("at {rtt_ms:.0} ms RTT: pure edge region"),
            }
        }
    }
    println!(
        "\nReading: longer inputs and faster networks push requests to the \
         cloud;\nhigher RTT expands the edge region — exactly the tradeoff \
         of paper Fig. 2b."
    );
    Ok(())
}
