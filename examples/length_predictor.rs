//! Output-length prediction demo (the paper's §II-B): fit the linear
//! N→M regressor on each language pair's corpus (with ParaCrawl-style
//! prefiltering) and show predictions vs ground truth, plus the effect
//! of skipping the prefilter.
//!
//! ```sh
//! cargo run --release --offline --example length_predictor
//! ```

use cnmt::corpus::{prefilter, CorpusGenerator, LangPair, PrefilterRules};
use cnmt::metrics::OnlineStats;
use cnmt::predictor::N2mRegressor;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    for pair in LangPair::ALL {
        let mut gen = CorpusGenerator::new(pair, 2024);
        let corpus = gen.take(30_000);
        let rules = PrefilterRules::default();
        let (_kept, stats) = prefilter(&corpus, &rules);

        let with = N2mRegressor::fit(&corpus, &rules)?;
        let without = N2mRegressor::fit_raw(&corpus)?;
        let truth = pair.params();

        println!("=== {} ===", pair.id());
        println!(
            "corpus: {} pairs, prefilter dropped {:.1}%",
            corpus.len(),
            stats.drop_rate() * 100.0
        );
        println!(
            "truth:          M = {:.3} N + {:.3}",
            truth.gamma, truth.delta
        );
        println!(
            "fit (filtered): M = {:.3} N + {:.3}   (R2 {:.3}, MSE {:.2})",
            with.gamma, with.delta, with.r2, with.mse
        );
        println!(
            "fit (raw):      M = {:.3} N + {:.3}   (R2 {:.3}, MSE {:.2})  <- outliers hurt",
            without.gamma, without.delta, without.r2, without.mse
        );

        // Held-out accuracy.
        let mut holdout_gen = CorpusGenerator::new(pair, 777);
        let mut abs_err = OnlineStats::new();
        for p in holdout_gen.take(5_000) {
            if p.outlier {
                continue;
            }
            abs_err.push((with.predict(p.n()) - p.m_real as f64).abs());
        }
        println!(
            "held-out |M̂ - M|: mean {:.2} tokens (max {:.0})",
            abs_err.mean(),
            abs_err.max()
        );
        for n in [4usize, 12, 24, 48] {
            println!("  N = {n:>2}  ->  M̂ = {:>5.1}", with.predict(n));
        }
        println!();
    }
    Ok(())
}
